"""Cross-pattern kernel fusion (docs/fusion.md): parity and legality.

The non-negotiable bar: a heterogeneous batch served through the fused
path must produce fragments BYTE-IDENTICAL to the unfused path (and to
the per-request numpy oracle) on every selector backend -- mixed data
and count segments, empty-Omega and wildcard edges included. Legality
is conservative in the spirit of DaCe's state-fusion tests: declared
dependencies and capacity ceilings refuse to fuse and fall back to
per-group launches, with the SAME bytes.
"""
import numpy as np
import pytest

from repro.core import (BrTPFServer, Request, ServerConfig, TriplePattern,
                        TripleStore, UNBOUND, brtpf_select_with_cnt,
                        encode_var, fragment_to_wire)
from repro.core.kernel_selectors import (FusedSegment, KernelSelector,
                                         MAX_FUSED_SEGMENTS,
                                         MAX_FUSED_STREAM, fusion_legality)
from repro.core.wire import dumps

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:          # pragma: no cover - minimal environment
    hypothesis = None

V = encode_var

pytestmark = pytest.mark.tier1

BACKENDS = ["numpy", "kernel", "sharded"]


def make_store(seed=0, n=600, terms=15):
    rng = np.random.default_rng(seed)
    return TripleStore(np.unique(
        rng.integers(0, terms, size=(n, 3)).astype(np.int32), axis=0))


def rand_omega(rng, m, v=2, terms=15, unbound_frac=0.3):
    om = rng.integers(0, terms, size=(m, v)).astype(np.int32)
    om[rng.random((m, v)) < unbound_frac] = UNBOUND
    return om


def make_server(store, backend, fuse, **extra):
    cfg = ServerConfig(selector_backend=backend, fuse_patterns=fuse,
                       max_mpr=30, **extra)
    if backend == "sharded":
        cfg = ServerConfig(selector_backend=backend, fuse_patterns=fuse,
                           max_mpr=30, shard_window=256, **extra)
    return BrTPFServer(store, cfg)


def hetero_batch(rng, count_probes=True):
    """A heterogeneous batch: >= 4 distinct patterns, mixed Omega
    shapes (brTPF, TPF/None, empty-Omega, full wildcard), and --
    optionally -- interleaved Definition-2 count probes."""
    reqs = [
        Request(pattern=TriplePattern(V(0), 3, V(1)),
                omega=rand_omega(rng, 6)),
        Request(pattern=TriplePattern(5, V(0), V(1)),
                omega=rand_omega(rng, 4)),
        Request(pattern=TriplePattern(V(0), V(1), 7),
                omega=rand_omega(rng, 9)),
        # TPF member: no Omega at all
        Request(pattern=TriplePattern(V(0), 2, V(1))),
        # empty-Omega edge: zero mappings behaves as TPF
        Request(pattern=TriplePattern(V(0), 5, V(1)),
                omega=np.empty((0, 2), np.int32)),
        # full wildcard pattern
        Request(pattern=TriplePattern(V(0), V(1), V(2)),
                omega=rand_omega(rng, 3, v=3)),
        # repeated-variable pattern
        Request(pattern=TriplePattern(V(0), 4, V(0)),
                omega=rand_omega(rng, 5, v=1)),
    ]
    if count_probes:
        reqs += [
            Request(pattern=TriplePattern(V(0), 3, V(1)),
                    omega=rand_omega(rng, 5), count_only=True),
            Request(pattern=TriplePattern(9, V(0), V(1)),
                    count_only=True),
        ]
    return reqs


def wire_bytes(frags):
    return [dumps(fragment_to_wire(f)) for f in frags]


class TestFusedBatchParity:
    """Fused vs unfused vs per-request oracle, all three backends."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_hetero_batch_byte_identical(self, backend, seed):
        store = make_store(seed)
        reqs = hetero_batch(np.random.default_rng(seed))

        fused = make_server(store, backend, fuse=True)
        unfused = make_server(store, backend, fuse=False)
        oracle = make_server(store, "numpy", fuse=False)

        got = wire_bytes(fused.handle_batch(reqs))
        want_unfused = wire_bytes(unfused.handle_batch(reqs))
        want_oracle = wire_bytes([oracle.handle(r) for r in reqs])
        assert got == want_unfused == want_oracle

        if backend != "numpy":
            # the fused server actually fused (>= 2 segments shared a
            # launch) and the unfused server never did
            assert fused.counters.fused_launches >= 1
            assert fused.counters.fused_segments \
                   >= 2 * fused.counters.fused_launches
            assert unfused.counters.fused_launches == 0
            # the whole point: strictly fewer launches than unfused
            assert fused.counters.kernel_launches \
                   < unfused.counters.kernel_launches

    @pytest.mark.parametrize("backend", ["kernel", "sharded"])
    def test_count_only_batch(self, backend):
        """An all-count batch fuses too, and count fragments carry
        cnt-only payloads identical to the oracle's."""
        store = make_store(3)
        rng = np.random.default_rng(3)
        reqs = [Request(pattern=TriplePattern(V(0), p, V(1)),
                        omega=rand_omega(rng, 4), count_only=True)
                for p in (2, 3, 5, 7)]
        fused = make_server(store, backend, fuse=True)
        oracle = make_server(store, "numpy", fuse=False)
        got = wire_bytes(fused.handle_batch(reqs))
        want = wire_bytes([oracle.handle(r) for r in reqs])
        assert got == want
        for frag in fused.handle_batch(reqs):
            assert frag.data.shape[0] == 0   # counts never stream rows

    @pytest.mark.parametrize("backend", ["kernel", "sharded"])
    def test_paging_through_fused_prefill(self, backend):
        """Page 1+ requests served off a fused prefill page exactly
        like the oracle pages its per-request selection."""
        store = make_store(4)
        rng = np.random.default_rng(4)
        base = hetero_batch(rng, count_probes=False)
        fused = make_server(store, backend, fuse=True, page_size=8)
        oracle = make_server(store, "numpy", fuse=False, page_size=8)
        first = fused.handle_batch(base)
        for req, frag in zip(base, first):
            want = oracle.handle(req)
            assert dumps(fragment_to_wire(frag)) \
                   == dumps(fragment_to_wire(want))
            page = 1
            while want.has_next:
                nxt = Request(pattern=req.pattern, omega=req.omega,
                              page=page)
                want = oracle.handle(nxt)
                got = fused.handle(nxt)
                assert dumps(fragment_to_wire(got)) \
                       == dumps(fragment_to_wire(want))
                page += 1


class TestFusionLegality:
    """Conservative, explicit refusals with a documented fallback."""

    def _segments(self, store, rng, n=3, depends=()):
        segs = []
        for i, p in enumerate((2, 3, 5, 7, 11)[:n]):
            segs.append(FusedSegment(
                tp=TriplePattern(V(0), p, V(1)),
                omegas=[rand_omega(rng, 4)],
                depends_on=(0,) if i in depends else ()))
        return segs

    def test_dependent_segments_refuse(self):
        store = make_store(5)
        rng = np.random.default_rng(5)
        segs = self._segments(store, rng, n=3, depends=(1,))
        reason = fusion_legality(segs, stream_rows=1024, slot_table=64)
        assert reason is not None and "dependent" in reason

    def test_capacity_ceilings_refuse(self):
        store = make_store(5)
        rng = np.random.default_rng(5)
        segs = self._segments(store, rng, n=3)
        assert "segment count" in fusion_legality(
            segs, stream_rows=1024, slot_table=64, max_segments=2)
        assert "candidate stream" in fusion_legality(
            segs, stream_rows=MAX_FUSED_STREAM + 1, slot_table=64)
        assert "slot table" in fusion_legality(
            segs, stream_rows=1024, slot_table=64, max_slots=63)
        assert fusion_legality(segs, stream_rows=1024,
                               slot_table=64) is None
        assert MAX_FUSED_SEGMENTS >= 2

    def test_dependent_segments_fall_back_to_per_group(self):
        """select_fused with a declared dependency: no fused launch is
        recorded, results still byte-match the oracle."""
        store = make_store(6)
        rng = np.random.default_rng(6)
        segs = self._segments(store, rng, n=3, depends=(2,))
        sel = KernelSelector(store)
        results = sel.select_fused(segs)
        assert all(rec.segments == 1 for rec in sel.launches)
        assert len(sel.launches) >= 2   # one grouped launch per segment
        for seg, rows in zip(segs, results):
            for om, (data, cnt) in zip(seg.omegas, rows):
                want, wcnt = brtpf_select_with_cnt(store, seg.tp, om)
                np.testing.assert_array_equal(data, want)
                assert cnt == wcnt

    def test_independent_segments_fuse_into_one_launch(self):
        store = make_store(7)
        rng = np.random.default_rng(7)
        segs = self._segments(store, rng, n=3)
        sel = KernelSelector(store)
        results = sel.select_fused(segs)
        fused = [rec for rec in sel.launches if rec.segments >= 2]
        assert len(fused) == 1
        assert fused[0].segments == 3
        assert fused[0].cand_rows > 0
        for seg, rows in zip(segs, results):
            for om, (data, cnt) in zip(seg.omegas, rows):
                want, wcnt = brtpf_select_with_cnt(store, seg.tp, om)
                np.testing.assert_array_equal(data, want)
                assert cnt == wcnt


if hypothesis is not None:
    @st.composite
    def batches(draw):
        seed = draw(st.integers(0, 2**16))
        rng = np.random.default_rng(seed)
        n_pat = draw(st.integers(2, 5))
        preds = draw(st.lists(st.integers(0, 12), min_size=n_pat,
                              max_size=n_pat, unique=True))
        reqs = []
        for p in preds:
            kind = draw(st.sampled_from(["brtpf", "tpf", "count"]))
            om = (rand_omega(rng, draw(st.integers(1, 8)))
                  if kind != "tpf" else None)
            reqs.append(Request(pattern=TriplePattern(V(0), p, V(1)),
                                omega=om, count_only=kind == "count"))
        return seed, reqs

    class TestFusionPropertySweep:
        @settings(max_examples=25, deadline=None)
        @given(batches())
        def test_fused_equals_oracle(self, batch):
            seed, reqs = batch
            store = make_store(seed % 7)
            fused = make_server(store, "kernel", fuse=True)
            oracle = make_server(store, "numpy", fuse=False)
            got = wire_bytes(fused.handle_batch(reqs))
            want = wire_bytes([oracle.handle(r) for r in reqs])
            assert got == want
