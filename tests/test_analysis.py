"""Self-tests for the repro-lint static analyzer (docs/analysis.md).

Each rule has a bad/good fixture pair under tests/fixtures/analysis/:
the bad snippet must yield exactly one finding with the right rule id
on the line marked ``# BAD``, the good twin must come back clean. The
final test is the live gate: the repo's own tree must be finding-free,
which is what CI's static-analysis job enforces.
"""
import json
from pathlib import Path

import pytest

from repro.analysis import (ALL_RULES, SEVERITY_ERROR, load_context,
                            run_analysis)
from repro.analysis.__main__ import main as analysis_main

pytestmark = pytest.mark.tier1

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"

# (fixture stem, expected rule id) -- covers all four rule groups:
# kernel-launch safety, cache coherence, accounting, async safety,
# plus the dead-code rules.
CASES = [
    ("kl001", "KL001"),
    ("kl002", "KL002"),
    ("kl003", "KL003"),
    ("kl004", "KL004"),
    ("kl005", "KL005"),
    ("cc001", "CC001"),
    ("cc002", "CC002"),
    ("cc003", "CC003"),
    ("ac001", "AC001"),
    ("ac002", "AC002"),
    ("as001", "AS001"),
    ("as001_asgi", "AS001"),
    ("dc001", "DC001"),
    ("dc002", "DC002"),
    ("rs001", "RS001"),
]


def _findings(*paths):
    return run_analysis(load_context([str(p) for p in paths]))


def _marked_line(path: Path) -> int:
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        if "# BAD" in line:
            return lineno
    raise AssertionError(f"{path} has no '# BAD' marker")


@pytest.mark.parametrize("stem,rule", CASES)
def test_bad_fixture_yields_one_finding(stem, rule):
    path = FIXTURES / f"{stem}_bad.py"
    findings = _findings(path)
    assert len(findings) == 1, findings
    f = findings[0]
    assert f.rule == rule
    assert f.severity == SEVERITY_ERROR
    assert f.line == _marked_line(path)
    assert f.file == path.name


@pytest.mark.parametrize("stem,rule", CASES)
def test_good_fixture_is_clean(stem, rule):
    assert _findings(FIXTURES / f"{stem}_good.py") == []


def test_ac003_bad_budget_key_flagged():
    findings = _findings(FIXTURES / "ac003_bad")
    assert [f.rule for f in findings] == ["AC003"]
    f = findings[0]
    assert f.severity == SEVERITY_ERROR
    assert "bogus_metric" in f.message
    budgets = FIXTURES / "ac003_bad" / "budgets.json"
    lines = budgets.read_text().splitlines()
    assert "bogus_metric" in lines[f.line - 1]


def test_ac003_good_budgets_resolve():
    assert _findings(FIXTURES / "ac003_good") == []


def test_cli_exit_codes():
    assert analysis_main([str(FIXTURES / "kl001_bad.py")]) == 1
    assert analysis_main([str(FIXTURES / "kl001_good.py")]) == 0


def test_cli_json_format(capsys):
    rc = analysis_main([str(FIXTURES / "kl001_bad.py"),
                        "--format", "json"])
    assert rc == 1
    report = json.loads(capsys.readouterr().out)
    assert report["counts"]["error"] == 1
    (finding,) = report["findings"]
    assert finding["rule"] == "KL001"
    assert set(finding) == {"rule", "severity", "file", "line", "col",
                            "message"}


def test_cli_select_filters_rules():
    # The KL001 fixture is clean under every other rule, so selecting
    # an unrelated rule must exit 0.
    bad = str(FIXTURES / "kl001_bad.py")
    assert analysis_main([bad, "--select", "AS001"]) == 0
    assert analysis_main([bad, "--select", "KL001"]) == 1


def test_rule_ids_unique():
    ids = [rule.rule_id for rule in ALL_RULES]
    assert len(ids) == len(set(ids))


def test_live_repo_is_finding_free():
    """The regression gate: the repo's own src/ + benchmarks/ trees
    (and benchmarks/budgets.json) carry no error-severity findings."""
    ctx = load_context([])
    assert (ctx.root / "src" / "repro").is_dir()
    assert ctx.budgets_path is not None
    findings = run_analysis(ctx)
    errors = [f for f in findings if f.severity == SEVERITY_ERROR]
    assert errors == [], "\n".join(f.format() for f in errors)
