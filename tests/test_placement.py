"""Workload-aware placement planner + cutover (docs/federation.md,
"Placement").

Host-side units pin the planner's contracts: the heat log is a bounded
sliding window, weighted-quantile boundaries equalize expected launches
per shard (and degrade to the equal split when the log is cold), an
un-splittable single-key hot spot triggers hot-range replication, and
the ``shard_of`` convention (cut keys start the shard to their right)
matches what ``FederatedStore._build_placed`` assumes.

The subprocess test is the end-to-end gate on a real 4-device mesh:
a hand-built :class:`Placement` with an explicit replica range must
serve byte-identical fragments to the numpy oracle while the routed
launch path spreads the replicated range across its holders, and a
live ``repartition()`` cutover under Zipf-skewed traffic must both
keep parity and cut the per-shard launch imbalance.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core.metrics import rebalance_report, shard_balance
from repro.core.placement import (HeatLog, HeatRecord, Placement,
                                  ReplicaRange, dataset_keys,
                                  equal_boundaries, heat_weights,
                                  plan_placement, weighted_boundaries)

pytestmark = pytest.mark.tier1


# -- heat log ---------------------------------------------------------------


def test_heatlog_is_bounded_sliding_window():
    log = HeatLog(capacity=4)
    for i in range(10):
        log.record("spo", lo_key=i, hi_key=i, launches=i)
    assert len(log) == 4
    # oldest evicted first: only the last 4 records survive
    assert [r.lo_key for r in log.records("spo")] == [6, 7, 8, 9]
    assert log.total_launches == 6 + 7 + 8 + 9


def test_heatlog_rejects_nonpositive_capacity():
    with pytest.raises(ValueError):
        HeatLog(capacity=0)


def test_heatlog_records_filter_by_order():
    log = HeatLog()
    log.record("spo", 1, 2)
    log.record("pos", 3, 4)
    assert [r.order for r in log.records("pos")] == ["pos"]
    assert len(log.records()) == 2


# -- weights + boundaries ---------------------------------------------------


def test_heat_weights_spread_uniformly_over_range():
    keys = np.arange(10, dtype=np.int64)
    rec = HeatRecord("spo", lo_key=2, hi_key=5, launches=8)
    w = heat_weights(keys, [rec], base=0.0)
    expect = np.zeros(10)
    expect[2:6] = 2.0          # 8 launches over 4 keys, bounds inclusive
    np.testing.assert_allclose(w, expect)


def test_weighted_boundaries_equalize_per_shard_mass():
    keys = np.arange(1000, dtype=np.int64)
    # all heat on the first 100 keys: cuts must move into the hot band
    recs = [HeatRecord("spo", 0, 99, launches=100)]
    w = heat_weights(keys, recs, base=1e-6)
    bounds = weighted_boundaries(keys, w, shards=4)
    assert bounds.shape == (3,)
    assert np.all(np.diff(bounds) >= 0)
    assert bounds.max() < 100     # every cut lands inside the hot band
    assign = np.searchsorted(bounds, keys, side="right")
    shard_w = np.bincount(assign, weights=w, minlength=4)
    assert shard_w.max() / shard_w.mean() < 1.3


def test_weighted_boundaries_zero_mass_falls_back_to_equal():
    keys = np.arange(64, dtype=np.int64) * 3
    bounds = weighted_boundaries(keys, np.zeros(64), shards=4)
    np.testing.assert_array_equal(bounds, equal_boundaries(keys, 4))


def test_equal_boundaries_degenerate_shapes():
    assert equal_boundaries(np.arange(10, dtype=np.int64), 1).size == 0
    assert equal_boundaries(np.empty(0, dtype=np.int64), 4).size == 0


def test_shard_of_cut_key_starts_right_shard():
    p = Placement(boundaries={"spo": np.array([10, 20], dtype=np.int64)})
    got = p.shard_of("spo", np.array([5, 10, 11, 20, 25]))
    np.testing.assert_array_equal(got, [0, 1, 1, 2, 2])


# -- placement planning -----------------------------------------------------


def _keys_by_order(n=512):
    rng = np.random.default_rng(0)
    triples = np.unique(
        rng.integers(0, 40, size=(n, 3)).astype(np.int32), axis=0)
    return dataset_keys(triples)


def test_plan_placement_cold_log_is_near_equal_split():
    keys_by_order = _keys_by_order()
    placement = plan_placement(HeatLog(), keys_by_order, shards=4)
    assert not placement.has_replicas
    for name, keys in keys_by_order.items():
        bounds = placement.boundaries[name]
        assert bounds.shape == (3,)
        counts = np.bincount(
            np.searchsorted(bounds, keys, side="right"), minlength=4)
        # uniform base weight -> per-shard key counts within one key of
        # the equal split
        assert counts.max() - counts.min() <= 2


def test_plan_placement_single_shard_has_no_cuts():
    placement = plan_placement(HeatLog(), _keys_by_order(), shards=1)
    assert all(b.size == 0 for b in placement.boundaries.values())
    assert not placement.has_replicas


def test_plan_placement_replicates_single_key_hotspot():
    """All heat on ONE key: no boundary cut can split it, so the whole
    mass collapses onto one shard and the planner must emit a replica
    range for it (home = the hot shard, copies elsewhere)."""
    keys = np.arange(1000, dtype=np.int64)
    log = HeatLog()
    for _ in range(50):
        log.record("spo", lo_key=500, hi_key=500, launches=10)
    placement = plan_placement(log, {"spo": keys}, shards=4)
    assert placement.has_replicas
    (rr,) = placement.replicas["spo"]
    assert (rr.lo_key, rr.hi_key) == (500, 500)
    hot = int(placement.shard_of("spo", np.array([500]))[0])
    assert rr.home == hot
    assert rr.replicas and hot not in rr.replicas
    assert rr.holders[0] == hot
    assert set(rr.holders) == {hot, *rr.replicas}


def test_plan_placement_splittable_hot_band_needs_no_replicas():
    """A hot band wider than a shard is balanced by boundaries alone --
    replication is reserved for ranges the quantile cuts cannot split."""
    keys = np.arange(1000, dtype=np.int64)
    log = HeatLog()
    log.record("spo", lo_key=0, hi_key=399, launches=400)
    placement = plan_placement(log, {"spo": keys}, shards=4)
    assert not placement.has_replicas
    assign = np.searchsorted(
        placement.boundaries["spo"], keys, side="right")
    w = heat_weights(keys, log.records("spo"),
                     base=0.05 * 400 / keys.size)
    shard_w = np.bincount(assign, weights=w, minlength=4)
    assert shard_w.max() / shard_w.mean() < 1.25


# -- metrics schema ---------------------------------------------------------


def test_shard_balance_imbalance_is_max_over_mean():
    bal = shard_balance([9, 1, 1, 1], [90, 10, 10, 10], [9, 1, 1, 1])
    assert bal["launches"] == [9, 1, 1, 1]
    assert bal["imbalance"] == pytest.approx(9 / 3)
    assert shard_balance([0, 0], [0, 0], [0, 0])["imbalance"] == 0.0


def test_rebalance_report_drop_ratio():
    uniform = shard_balance([8, 0, 0, 0], [0] * 4, [0] * 4)
    heat = shard_balance([2, 2, 2, 2], [0] * 4, [0] * 4)
    report = rebalance_report(uniform, heat)
    assert report["imbalance_uniform"] == pytest.approx(4.0)
    assert report["imbalance_heat"] == pytest.approx(1.0)
    assert report["imbalance_drop"] == pytest.approx(4.0)
    assert report["shard_launches_uniform"] == [8, 0, 0, 0]
    assert report["shard_launches_heat"] == [2, 2, 2, 2]


# -- end-to-end: placed mesh + live cutover ---------------------------------


def test_placed_mesh_subprocess():
    """True 4-device check, two phases:

    1. a hand-built Placement (non-uniform SPO cuts + an explicit
       ReplicaRange) built into the FederatedStore must serve fragments
       byte-identical to the numpy oracle, with the routed launch path
       charging the replicated range to BOTH holders (least-loaded
       owner alternation) instead of double-streaming it;
    2. a live server (placement_policy="heat") under Zipf-skewed
       traffic must survive a repartition() cutover with byte parity
       and a measurably lower per-shard launch imbalance.
    """
    code = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
import numpy as np, jax
from repro.core import (BrTPFServer, Request, ServerConfig, TriplePattern,
                        TripleStore, UNBOUND, encode_var)
from repro.core.federation import FederatedStore
from repro.core.placement import Placement, ReplicaRange, dataset_keys
V = encode_var
assert len(jax.devices()) == 4

# subjects are contiguous blocks in SPO key space (8 triples each)
n_subj, per_subj = 64, 8
s = np.repeat(np.arange(n_subj), per_subj) + 100
p = np.tile(np.arange(per_subj), n_subj) % 4 + 1
o = np.arange(s.size) + 10_000
store = TripleStore(np.stack([s, p, o], axis=1).astype(np.int32))
keys = dataset_keys(store.triples)["spo"]

# ---- phase 1: manual placement, explicit replica range ----
# non-uniform cuts: shard 0 owns 50% of keys, the rest split the tail;
# replicate subject block 10..11 (home shard 0 -> copy on shard 2)
n = keys.size
cuts = np.array([keys[n // 2], keys[5 * n // 8], keys[6 * n // 8]],
                dtype=np.int64)
lo_key = int(keys[10 * per_subj])
hi_key = int(keys[12 * per_subj - 1])
manual = Placement(
    boundaries={"spo": cuts},
    replicas={"spo": (ReplicaRange("spo", lo_key, hi_key, home=0,
                                   replicas=(2,)),)})
oracle = BrTPFServer(store, ServerConfig(selector_backend="numpy"))
srv = BrTPFServer(store, ServerConfig(selector_backend="sharded",
                                      shard_window=16))
placed = FederatedStore.build(store.triples, srv.federated.mesh,
                              placement=manual)
assert placed.placement is not None and placed.placement.has_replicas
srv.federated = placed
srv._selector.rebind(placed)
srv.fragments.clear()

om = np.array([[2, UNBOUND], [3, UNBOUND]], np.int32)
hot = [Request(TriplePattern(100 + subj, V(0), V(1)),
               np.roll(om, k, axis=0) + np.int32(0), page=0)
       for subj in (10, 11) for k in (0, 1)]
cold = [Request(TriplePattern(100 + subj, V(0), V(1)), om, page=0)
        for subj in (5, 40, 60)]
for req in hot * 3 + cold:
    f_np = oracle.handle(req)
    f_sh = srv.handle(req)
    np.testing.assert_array_equal(f_np.data, f_sh.data)
    assert f_np.cnt == f_sh.cnt and f_np.has_next == f_sh.has_next
pages = srv.shard_launch_snapshot()
# routed dedup: the replicated block is charged to holders {0, 2}, and
# least-loaded alternation gives BOTH holders work
assert pages[0] > 0 and pages[2] > 0, pages.tolist()
print("PLACED_PARITY_OK", pages.tolist())

# ---- phase 2: live heat cutover under skew ----
rng = np.random.default_rng(7)
live = BrTPFServer(store, ServerConfig(selector_backend="sharded",
                                       shard_window=16,
                                       placement_policy="heat"))
ranks = np.arange(1, n_subj + 1, dtype=np.float64)
wts = ranks ** -2.0
wts /= wts.sum()
def traffic():
    reqs = []
    for _ in range(160):
        subj = int(rng.choice(n_subj, p=wts)) + 100
        pr = rng.choice(4, size=2, replace=False) + 1
        omega = np.array([[int(x), UNBOUND] for x in pr], np.int32)
        reqs.append(Request(TriplePattern(subj, V(0), V(1)), omega, 0))
    return reqs
for req in traffic():
    live.handle(req)
uni = live.metrics_snapshot()["shards"]
live.repartition()
live.reset_counters()
sample = traffic()
for req in sample:
    live.handle(req)
heat = live.metrics_snapshot()["shards"]
assert heat["imbalance"] < uni["imbalance"] / 1.5, (uni, heat)
for req in sample[:8]:
    f_np = oracle.handle(req)
    f_sh = live.handle(req)
    np.testing.assert_array_equal(f_np.data, f_sh.data)
    assert f_np.cnt == f_sh.cnt and f_np.has_next == f_sh.has_next
print("CUTOVER_OK", round(uni["imbalance"], 3),
      "->", round(heat["imbalance"], 3))
"""
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "PLACED_PARITY_OK" in proc.stdout
    assert "CUTOVER_OK" in proc.stdout
