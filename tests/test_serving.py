"""Serving engine tests: generation consistency and shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, reduced_for_smoke
from repro.models.model import build_model
from repro.serving.engine import ServingEngine


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "rwkv6-7b"])
def test_generate_matches_stepwise_forward(arch):
    """Engine output == argmax chain of full forward passes."""
    cfg = reduced_for_smoke(all_archs()[arch])
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)

    engine = ServingEngine(model, params, max_batch=1, max_seq=24)
    res = engine.generate([prompt], max_new_tokens=5)[0]

    # reference: grow the sequence with full forwards
    seq = list(prompt)
    for _ in range(5):
        logits, _ = model.forward(params,
                                  jnp.asarray([seq], jnp.int32))
        seq.append(int(jnp.argmax(logits[0, -1])))
    np.testing.assert_array_equal(res.tokens, np.asarray(seq[6:]))


def test_generate_batch_isolated():
    """Requests in one batch do not contaminate each other."""
    cfg = reduced_for_smoke(all_archs()["qwen2-1.5b"])
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    p1 = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)

    eng2 = ServingEngine(model, params, max_batch=2, max_seq=16)
    both = eng2.generate([p1, p2], max_new_tokens=4)
    eng1 = ServingEngine(model, params, max_batch=2, max_seq=16)
    solo = eng1.generate([p1, p1], max_new_tokens=4)
    np.testing.assert_array_equal(both[0].tokens, solo[0].tokens)
