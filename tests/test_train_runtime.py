"""Training runtime tests: optimizer, checkpointing, failure recovery,
gradient compression, brTPF data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import shard_map
from repro.train import checkpoint as ckpt
from repro.train.grad_compress import (compress_with_feedback,
                                       compressed_psum_tree, dequantize,
                                       init_error_state, quantize)
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import (AdamW, apply_updates, constant_lr,
                                   warmup_cosine)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(learning_rate=constant_lr(0.1), weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            updates, state, _ = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clip_norm(self):
        opt = AdamW(learning_rate=constant_lr(0.1), clip_norm=1.0)
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        _, _, metrics = opt.update({"w": jnp.full((4,), 100.0)}, state,
                                   params)
        assert float(metrics["grad_norm"]) > 1.0  # pre-clip norm reported

    def test_schedule_warmup_cosine(self):
        sched = warmup_cosine(1.0, 10, 100)
        assert float(sched(jnp.int32(0))) == 0.0
        assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
        assert float(sched(jnp.int32(100))) < 0.2


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 7, tree)
        step, restored = ckpt.restore(str(tmp_path), tree)
        assert step == 7
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), tree, restored)

    def test_partial_write_ignored(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a crash mid-write: directory without COMMIT
        bad = tmp_path / "step_00000002"
        bad.mkdir()
        (bad / "manifest.json").write_text("{}")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_corrupt_falls_back(self, tmp_path):
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        ckpt.save(str(tmp_path), 2, tree)
        # corrupt the newest: truncate a leaf
        leaf = tmp_path / "step_00000002" / "leaf_00000.npy"
        leaf.write_bytes(leaf.read_bytes()[:16])
        step, _ = ckpt.restore(str(tmp_path), tree)
        assert step == 1

    def test_cleanup_keeps_n(self, tmp_path):
        tree = _tree()
        for s in range(5):
            ckpt.save(str(tmp_path), s, tree)
        ckpt.cleanup(str(tmp_path), keep=2)
        assert ckpt.valid_steps(str(tmp_path)) == [3, 4]

    def test_async_checkpointer(self, tmp_path):
        tree = _tree()
        ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
        ac.save(3, tree)
        ac.wait()
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_resharding_restore(self, tmp_path):
        """Elastic path: restore with explicit (single-device) shardings."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        tree = _tree()
        ckpt.save(str(tmp_path), 1, tree)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        step, restored = ckpt.restore(str(tmp_path), tree, sh)
        assert step == 1
        assert all(isinstance(x, jax.Array)
                   for x in jax.tree.leaves(restored))


# ---------------------------------------------------------------------------
# Trainer: failure recovery + stragglers
# ---------------------------------------------------------------------------

def _toy_setup(tmp_path, total=30, ckpt_every=5):
    from repro.train.optimizer import AdamW, constant_lr

    opt = AdamW(learning_rate=constant_lr(0.05), weight_decay=0.0)
    params = {"w": jnp.array(4.0)}
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def loss_fn(p):
            return jnp.square(p["w"] - batch["target"]).sum()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params)
        return (apply_updates(params, updates), opt_state,
                {"loss": loss})

    cfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                        ckpt_every=ckpt_every, max_restarts=3)
    return cfg, step_fn, params, opt_state


def _data():
    while True:
        yield {"target": jnp.array(1.0)}


class TestTrainer:
    def test_runs_and_learns(self, tmp_path):
        cfg, step_fn, params, opt_state = _toy_setup(tmp_path)
        tr = Trainer(cfg, step_fn, params, opt_state)
        report = tr.train(_data())
        assert report.steps_run == 30
        assert report.final_loss < report.losses[0]

    def test_failure_recovery_resumes_from_checkpoint(self, tmp_path):
        cfg, step_fn, params, opt_state = _toy_setup(tmp_path)
        fired = {"done": False}

        def failure_hook(step):
            if step == 17 and not fired["done"]:
                fired["done"] = True
                raise RuntimeError("simulated node failure")

        tr = Trainer(cfg, step_fn, params, opt_state,
                     failure_hook=failure_hook)
        report = tr.train(_data())
        assert report.restarts == 1
        # resumed from the step-15 checkpoint and completed all 30 steps
        assert tr.step == 30
        # replayed steps 15..17 after the restore
        assert report.steps_run > 30
        assert report.final_loss < report.losses[0]

    def test_too_many_failures_raises(self, tmp_path):
        cfg, step_fn, params, opt_state = _toy_setup(tmp_path)

        def always_fail(step):
            raise RuntimeError("dead node")

        tr = Trainer(cfg, step_fn, params, opt_state,
                     failure_hook=always_fail)
        with pytest.raises(RuntimeError):
            tr.train(_data())

    def test_resume_across_trainer_instances(self, tmp_path):
        cfg, step_fn, params, opt_state = _toy_setup(tmp_path, total=10)
        tr = Trainer(cfg, step_fn, params, opt_state)
        tr.train(_data())
        # "process restart": a new trainer picks up at step 10's ckpt
        cfg2, step_fn2, params2, opt_state2 = _toy_setup(tmp_path,
                                                         total=20)
        tr2 = Trainer(cfg2, step_fn2, params2, opt_state2)
        assert tr2.try_resume()
        assert tr2.step == 10
        report = tr2.train(_data())
        assert tr2.step == 20 and report.steps_run == 10


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

class TestCompression:
    def test_quantize_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
        q, scale = quantize(g)
        err = np.abs(np.asarray(dequantize(q, scale) - g))
        assert err.max() <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """With error feedback, the *accumulated* dequantized signal
        tracks the accumulated gradient far better than without."""
        rng = np.random.default_rng(1)
        g_seq = [jnp.asarray(rng.normal(size=(64,)) * 0.01, jnp.float32)
                 for _ in range(50)]
        err = jnp.zeros((64,), jnp.float32)
        acc_fb = np.zeros(64)
        acc_nofb = np.zeros(64)
        acc_true = np.zeros(64)
        for g in g_seq:
            q, s, err = compress_with_feedback(g, err)
            acc_fb += np.asarray(dequantize(q, s))
            q2, s2 = quantize(g)
            acc_nofb += np.asarray(dequantize(q2, s2))
            acc_true += np.asarray(g)
        err_fb = np.abs(acc_fb - acc_true).mean()
        err_nofb = np.abs(acc_nofb - acc_true).mean()
        assert err_fb <= err_nofb + 1e-9

    def test_compressed_psum_single_device(self):
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        grads = {"w": jnp.asarray(np.random.default_rng(2).normal(
            size=(32,)), jnp.float32)}
        errs = init_error_state(grads)

        def fn(g, e):
            return compressed_psum_tree(g, e, "data")

        out, new_e = shard_map(
            fn, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False)(grads, errs)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(grads["w"]), atol=2e-2)


# ---------------------------------------------------------------------------
# brTPF data pipeline
# ---------------------------------------------------------------------------

class TestDataPipeline:
    def test_selection_and_batches(self):
        from repro.data.pipeline import BrTPFDataPipeline, SyntheticCorpus
        corpus = SyntheticCorpus.generate(num_docs=100, vocab_size=512,
                                          seed=3)
        pipe = BrTPFDataPipeline(
            corpus, "?d hasDomain code\n?d hasQuality q0",
            batch_size=4, seq_len=32)
        assert pipe.stats.selected_docs > 0
        assert pipe.stats.num_requests > 0
        it = iter(pipe)
        b = next(it)
        assert b["tokens"].shape == (4, 32)
        assert b["targets"].shape == (4, 32)
        # next-token alignment
        np.testing.assert_array_equal(b["tokens"][:, 1:],
                                      b["targets"][:, :-1])
        # selected docs actually satisfy the query
        d = corpus.dictionary
        dom = d.lookup("hasDomain")
        code = d.lookup("code")
        for doc in pipe.selected_docs:
            assert corpus.store.contains(
                np.array([doc, dom, code], np.int32))

    def test_empty_selection_raises(self):
        from repro.data.pipeline import BrTPFDataPipeline, SyntheticCorpus
        corpus = SyntheticCorpus.generate(num_docs=20, seed=4)
        corpus.dictionary.intern("nonexistent")
        with pytest.raises(ValueError):
            BrTPFDataPipeline(corpus, "?d hasDomain nonexistent",
                              batch_size=2, seq_len=16)
