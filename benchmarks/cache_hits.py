"""Paper Figure 4: cache-hit potential, TPF vs brTPF.

(a) #hits for LRU caches of increasing capacity (and unlimited);
(b) #hits with an unlimited cache across page sizes.

Validation targets (section 7.1): TPF #hits >> brTPF #hits at every
cache size; brTPF maxMpR=15 achieves ~150% of the #hits of maxMpR=30;
curves flatten once capacity covers all distinct requests; page size has
no impact on #hits.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core import LRUCache

from .common import emit, run_sequence, timed


def _hits(kind: str, mpr: int, cache_size: Optional[int],
          page_size: int = 100) -> int:
    cache = LRUCache(cache_size)
    server, _ = run_sequence(kind, page_size=page_size, max_mpr=mpr,
                             cache=cache)
    return cache.hits


def run(full: bool = False) -> Dict:
    sizes = ([2_500, 5_000, 10_000, 50_000, 100_000, 250_000, 500_000,
              None] if full else [2_500, 10_000, 50_000, None])
    out: Dict = {"by_size": {}, "by_pagesize": {}}
    for label, kind, mpr in [("tpf", "tpf", 30), ("brtpf15", "brtpf", 15),
                             ("brtpf30", "brtpf", 30)]:
        out["by_size"][label] = {}
        for cs in sizes:
            hits, dt = timed(_hits, kind, mpr, cs)
            out["by_size"][label][cs] = hits
            emit(f"cache_hits/{label}_size{cs or 'inf'}", dt * 1e6,
                 f"hits={hits}")

    pagesizes = [100, 500, 2000] if not full else [100, 250, 500, 1000,
                                                   2000]
    for label, kind, mpr in [("tpf", "tpf", 30), ("brtpf15", "brtpf", 15),
                             ("brtpf30", "brtpf", 30)]:
        out["by_pagesize"][label] = {}
        for ps in pagesizes:
            hits, dt = timed(_hits, kind, mpr, None, page_size=ps)
            out["by_pagesize"][label][ps] = hits
            emit(f"cache_hits/{label}_ps{ps}", dt * 1e6, f"hits={hits}")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
