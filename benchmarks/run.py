"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Default scales are CI-friendly;
``--full`` (or REPRO_BENCH_FULL=1) switches to the EXPERIMENTS.md
configuration. ``--only <prefix>`` restricts to one bench family.
"""
from __future__ import annotations

import sys


def main() -> None:
    full = "--full" in sys.argv
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]

    print("name,us_per_call,derived")
    benches = []
    from . import network_load, pagesize, throughput, cache_hits, kernels
    benches = [
        ("network_load", network_load.run),
        ("pagesize", pagesize.run),
        ("throughput", throughput.run),
        ("cache_hits", cache_hits.run),
        ("kernels", kernels.run),
    ]
    try:
        from . import roofline_report
        benches.append(("roofline", roofline_report.run))
    except ImportError:
        pass

    for name, fn in benches:
        if only and not name.startswith(only):
            continue
        fn(full=full)


if __name__ == "__main__":
    main()
