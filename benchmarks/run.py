"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Default scales are CI-friendly;
``--full`` (or REPRO_BENCH_FULL=1) switches to the EXPERIMENTS.md
configuration. ``--only <prefix>`` restricts to one bench family.
``--check-trajectory`` instead verifies that the current PR has landed
a trajectory entry in ``BENCH_throughput.json`` (the CI guard against
the empty-trajectory regression: benchmark runs that forget to
``persist`` a headline).
"""
from __future__ import annotations

import json
import os
import sys


def check_trajectory() -> int:
    """Exit 0 iff ``BENCH_throughput.json`` has a trajectory entry for
    the current PR id (run AFTER the smoke benchmarks in CI)."""
    from .common import REPO_ROOT, pr_id
    path = os.path.join(REPO_ROOT, "BENCH_throughput.json")
    if not os.path.exists(path):
        print(f"trajectory FAIL: {path} missing")
        return 1
    with open(path) as fh:
        trajectory = json.load(fh).get("trajectory", [])
    pr = pr_id()
    entries = [e for e in trajectory if e.get("pr") == pr]
    if not entries:
        seen = [e.get("pr") for e in trajectory]
        print(f"trajectory FAIL: no entry for {pr} (have {seen})")
        return 1
    keys = sorted(k for e in entries for k in e if k != "pr")
    print(f"trajectory OK: {pr} present with {len(keys)} metric(s)")
    return 0


def main() -> None:
    if "--check-trajectory" in sys.argv:
        raise SystemExit(check_trajectory())
    full = "--full" in sys.argv
    only = None
    if "--only" in sys.argv:
        only = sys.argv[sys.argv.index("--only") + 1]

    print("name,us_per_call,derived")
    benches = []
    from . import (network_load, pagesize, throughput, cache_hits,
                   kernels, chaos)
    benches = [
        ("network_load", network_load.run),
        ("pagesize", pagesize.run),
        ("throughput", throughput.run),
        ("cache_hits", cache_hits.run),
        ("kernels", kernels.run),
        ("chaos", chaos.run),
    ]
    try:
        from . import roofline_report
        benches.append(("roofline", roofline_report.run))
    except ImportError:
        pass

    for name, fn in benches:
        if only and not name.startswith(only):
            continue
        fn(full=full)


if __name__ == "__main__":
    main()
