"""Paper Figure 2: network load -- #req and dataRecv, TPF vs brTPF.

Reproduces: (a) overall #req vs maxMpR, (b) overall dataRecv vs maxMpR,
(c,d) per-query better/worse counts, (e,f) difference-magnitude buckets
for maxMpR=30.

Validation targets (paper section 5.3): brTPF's overall #req falls
monotonically with maxMpR, down to a few percent of TPF's; dataRecv is
53.5%-79.6% of TPF's and also falls with maxMpR.
"""
from __future__ import annotations

from typing import Dict, List

from .common import emit, run_sequence, timed


def max_mpr_values(full: bool) -> List[int]:
    return list(range(5, 55, 5)) if full else [5, 15, 30, 50]


def run(full: bool = False) -> Dict:
    out: Dict = {"brtpf": {}}
    (server, tpf_results), t_tpf = timed(run_sequence, "tpf")
    tpf = {
        "req": server.counters.num_requests,
        "recv": server.counters.data_received,
        "per_query": [(r.num_requests, r.data_received, r.timed_out)
                      for _, r in tpf_results],
    }
    out["tpf"] = tpf
    emit("network_load/tpf", t_tpf * 1e6 / max(len(tpf_results), 1),
         f"req={tpf['req']};recv={tpf['recv']}")

    for mpr in max_mpr_values(full):
        (server, br_results), t_br = timed(
            run_sequence, "brtpf", max_mpr=mpr)
        row = {
            "req": server.counters.num_requests,
            "recv": server.counters.data_received,
            "per_query": [(r.num_requests, r.data_received, r.timed_out)
                          for _, r in br_results],
        }
        out["brtpf"][mpr] = row
        emit(f"network_load/brtpf_mpr{mpr}",
             t_br * 1e6 / max(len(br_results), 1),
             f"req={row['req']};recv={row['recv']};"
             f"req_frac={row['req'] / max(tpf['req'], 1):.3f};"
             f"recv_frac={row['recv'] / max(tpf['recv'], 1):.3f}")

    # Fig 2(c,d): per-query win counts at each maxMpR
    for mpr, row in out["brtpf"].items():
        better_req = worse_req = better_recv = worse_recv = 0
        for (tq, tr, _), (bq, br_, _) in zip(tpf["per_query"],
                                             row["per_query"],
                                             strict=True):
            better_req += bq < tq
            worse_req += bq > tq
            better_recv += br_ < tr
            worse_recv += br_ > tr
        row["wins"] = (better_req, worse_req, better_recv, worse_recv)
        emit(f"network_load/wins_mpr{mpr}", 0.0,
             f"req_better={better_req};req_worse={worse_req};"
             f"recv_better={better_recv};recv_worse={worse_recv}")

    # Fig 2(e,f): difference-magnitude buckets for maxMpR=30
    mpr30 = out["brtpf"].get(30)
    if mpr30:
        buckets = {}
        for (tq, _tr, _), (bq, _br, _) in zip(tpf["per_query"],
                                              mpr30["per_query"],
                                              strict=True):
            diff = tq - bq
            mag = 0
            while abs(diff) >= 10 ** (mag + 1):
                mag += 1
            key = f"{'+' if diff >= 0 else '-'}1e{mag}"
            buckets[key] = buckets.get(key, 0) + 1
        mpr30["req_diff_buckets"] = buckets
        emit("network_load/diff_buckets_mpr30", 0.0,
             ";".join(f"{k}={v}" for k, v in sorted(buckets.items())))
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
