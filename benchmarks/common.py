"""Shared benchmark fixtures: dataset, workload, engine runners.

Scales default small enough for one CPU core; pass ``--full`` to
``benchmarks.run`` (or use the env var ``REPRO_BENCH_FULL=1``) for the
EXPERIMENTS.md configuration.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Dict, Optional


from repro.core import (BrTPFClient, BrTPFServer, LRUCache, ServerConfig,
                        TPFClient)
from repro.data.watdiv import (WatDivData, WatDivScale, generate,
                               generate_workload)

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@dataclasses.dataclass
class BenchConfig:
    scale: WatDivScale
    num_queries: int
    request_budget: int
    seed: int = 0

    @classmethod
    def default(cls) -> "BenchConfig":
        if FULL:
            # ~0.5M triples, the paper's 145-query selection
            return cls(WatDivScale(users=20000, products=8000,
                                   reviews=30000, retailers=100,
                                   genres=60, cities=120, tags=300),
                       num_queries=145, request_budget=100_000)
        # ~25K triples, 48 queries: CI-friendly
        return cls(WatDivScale(users=1500, products=600, reviews=2500,
                               retailers=24, genres=30, cities=40,
                               tags=80),
                   num_queries=48, request_budget=15_000)


@functools.lru_cache(maxsize=2)
def dataset(seed: int = 0, full: Optional[bool] = None) -> WatDivData:
    cfg = BenchConfig.default()
    return generate(cfg.scale, seed=cfg.seed + seed)


@functools.lru_cache(maxsize=4)
def workload(seed: int = 1):
    cfg = BenchConfig.default()
    return tuple(generate_workload(dataset(), cfg.num_queries, seed=seed))


# Small-work fast path for the accelerated benchmark servers: below
# this many (post-pruning) candidate rows the selector routes to the
# numpy block evaluation instead of a kernel/window launch
# (BENCH_kernels.json shows the interpret-mode kernel losing to numpy
# outright at small work; on TPU the dispatch overhead dominates there).
FAST_PATH_ROWS = 256


def make_server(page_size: int = 100, max_mpr: int = 30,
                cache: Optional[LRUCache] = None,
                selector_backend: str = "numpy",
                shard_window: Optional[int] = None,
                fast_path_rows: int = FAST_PATH_ROWS,
                fuse_patterns: bool = True) -> BrTPFServer:
    config = ServerConfig(page_size=page_size, max_mpr=max_mpr,
                          selector_backend=selector_backend,
                          shard_window=shard_window,
                          fast_path_rows=fast_path_rows,
                          fuse_patterns=fuse_patterns)
    return BrTPFServer(dataset().store, config, cache=cache)


def run_sequence(client_kind: str, page_size: int = 100,
                 max_mpr: int = 30, cache: Optional[LRUCache] = None,
                 per_query: bool = False):
    """Execute the workload; returns (server, per-query results list)."""
    cfg = BenchConfig.default()
    server = make_server(page_size, max_mpr, cache)
    results = []
    for name, bgp in workload():
        if client_kind == "tpf":
            client = TPFClient(server, request_budget=cfg.request_budget)
        else:
            client = BrTPFClient(server, max_mpr=max_mpr,
                                 request_budget=cfg.request_budget)
        res = client.execute(bgp)
        results.append((name, res))
    return server, results


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def _jsonable(obj):
    """Best-effort conversion of benchmark results to JSON values."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _jsonable(getattr(obj, f.name))
                for f in dataclasses.fields(obj)
                # per-query latency lists blow up the tracked file
                if f.name != "qets"}
    if isinstance(obj, dict):
        return {k if isinstance(k, str) else repr(k): _jsonable(v)
                for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):          # numpy scalar
        return obj.item()
    if isinstance(obj, float):
        return round(obj, 6)
    return obj


def pr_id() -> str:
    """Identifier for the current PR in the benchmark trajectory:
    ``REPRO_PR`` if set, else the repo's commit count (each PR is one
    commit in this repo's history), else 'unversioned'."""
    env = os.environ.get("REPRO_PR")
    if env:
        return env
    try:
        import subprocess
        count = subprocess.run(
            ["git", "rev-list", "--count", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        if count.returncode == 0 and count.stdout.strip():
            return f"r{count.stdout.strip()}"
    except Exception:
        pass
    return "unversioned"


def persist(kind: str, results: Dict,
            headline: Optional[Dict] = None,
            section: Optional[str] = None) -> str:
    """Write results to ``BENCH_<kind>.json`` at the repo root.

    The file is committed per PR, so the current snapshot is diffable
    across the PR history; ``headline`` additionally APPENDS one
    trajectory entry (PR id + headline metrics) to the file's
    ``trajectory`` list, so the perf history (req/s,
    launches-per-request, candidates-streamed, ...) reads as a series
    instead of a single overwritten snapshot. Multiple benchmarks share
    one trajectory file (throughput + the latency load generator): a
    same-PR entry is MERGED key-wise, never replaced, so whichever runs
    second adds its metrics alongside the first's.

    ``section`` scopes the results write: instead of replacing the whole
    ``results`` payload, only ``results[section]`` is replaced (the
    latency run must not wipe the throughput snapshot it shares a file
    with).
    """
    path = os.path.join(REPO_ROOT, f"BENCH_{kind}.json")
    trajectory = []
    existing_results: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as fh:
                existing = json.load(fh)
            trajectory = existing.get("trajectory", [])
            existing_results = existing.get("results", {})
        except Exception:
            trajectory = []
    if headline is not None:
        entry = {"pr": pr_id(), **_jsonable(headline)}
        # one merged entry per PR id: a re-run within a PR updates its
        # own keys in place and keeps sibling benchmarks' keys
        for prev in trajectory:
            if prev.get("pr") == entry["pr"]:
                entry = {**prev, **entry}
        trajectory = [e for e in trajectory if e.get("pr") != entry["pr"]]
        trajectory.append(entry)
    if section is not None:
        if not isinstance(existing_results, dict):
            existing_results = {}
        existing_results[section] = _jsonable(results)
        results_payload = existing_results
    else:
        results_payload = _jsonable(results)
    payload = {
        "config": _jsonable(dataclasses.asdict(BenchConfig.default())),
        "full": FULL,
        "results": results_payload,
    }
    if trajectory:
        payload["trajectory"] = trajectory
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path
