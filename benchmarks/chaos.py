"""Chaos benchmark: closed-loop load under seeded fault injection.

The brTPF line of work argues about *availability under load*; this is
the benchmark that measures it. A 4-replica
:class:`~repro.serving.router.ReplicaRouter` (shared WatDiv store,
kernel backend) runs under a deterministic
:class:`~repro.serving.faults.FaultPlan`:

* one replica (index 1) STALLS: after a handful of served requests,
  every subsequent request hangs far longer than any client deadline;
* every replica injects 5% transient transport errors (retryable 503s).

Sixteen closed-loop :class:`~repro.core.client.AsyncBrTPFClient`s drive
the WatDiv workload through a
:class:`~repro.serving.resilience.ResilientTransport` (per-request
deadline + per-attempt timeout, exponential backoff with full jitter,
hedging) over the loopback wire. The run asserts the whole resilience
story at once:

* **availability**: success rate over client-visible requests
  (``chaos_c16:success_rate`` budget, >= 0.999 -- retries + breaker
  failover must absorb the plan);
* **correctness**: every query that completes under faults returns
  byte-identical solutions to a fault-free sequential oracle
  (``chaos_c16:parity``) -- resilience must never change results;
* **tail latency**: p99 over the same requests
  (``chaos_c16:p99_latency_ms``) -- detouring around a stalled replica
  must cost bounded time, not hang;
* **regression-proofing (A/B)**: the SAME plan with resilience
  disabled (bare transport, deadlines only, no retries/failover) must
  demonstrably fail (``chaos_ab_c16:failed_queries`` >= 1) -- proving
  the fault plan has teeth and the pass above is earned.

Counters surface through ``GET /metrics``-schema snapshots read over
the transport itself (``resilience`` section: retries, hedges, shed,
breaker transitions/opens/failovers).
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import AsyncBrTPFClient, BrTPFClient, BrTPFServer
from repro.core.config import ServerConfig
from repro.core.metrics import chaos_summary
from repro.core.sim import split_workload
from repro.serving.faults import FaultPlan, FaultSpec
from repro.serving.resilience import ResilientTransport, RetryPolicy
from repro.serving.router import ReplicaRouter
from repro.serving.transport import LoopbackTransport

from .common import BenchConfig, FAST_PATH_ROWS, dataset, emit, persist, \
    workload
from .throughput import BUDGETS_PATH, SHARD_WINDOW, check_budgets

REPLICAS = 4
STALLED_REPLICA = 1
ERROR_RATE = 0.05
PLAN_SEED = 1608            # arXiv:1608.08148

# Client resilience tuning: a stalled attempt is cut at
# ATTEMPT_TIMEOUT_MS (feeding the breaker), leaving most of DEADLINE_MS
# for the retry that lands on a healthy replica.
DEADLINE_MS = 8000.0
ATTEMPT_TIMEOUT_MS = 300.0
MAX_ATTEMPTS = 10
# The bare A/B arm gets deadlines only (no retries): tight enough that
# a stalled request fails fast instead of padding the wall clock.
AB_DEADLINE_MS = 2000.0


def fault_plan(seed: int = PLAN_SEED) -> FaultPlan:
    """The canonical acceptance plan: stall 1 of 4 replicas, 5%
    injected transport errors everywhere."""
    return FaultPlan(
        seed=seed,
        default=FaultSpec(error_rate=ERROR_RATE),
        per_replica={STALLED_REPLICA: FaultSpec(
            error_rate=ERROR_RATE, stall_after=2, stall_s=30.0)})


class _OutcomeTransport:
    """Counts client-visible request outcomes (after whatever
    resilience sits below) and times them -- the success-rate and
    latency surface the budgets gate."""

    def __init__(self, inner) -> None:
        self.inner = inner
        self.ok = 0
        self.failed = 0
        self.samples_s: List[float] = []

    @property
    def max_mpr(self) -> int:
        return self.inner.max_mpr

    async def handle(self, req):
        t0 = time.perf_counter()
        try:
            frag = await self.inner.handle(req)
        except Exception:
            self.failed += 1
            raise
        self.ok += 1
        self.samples_s.append(time.perf_counter() - t0)
        return frag

    async def metrics(self) -> dict:
        return await self.inner.metrics()

    async def aclose(self) -> None:
        await self.inner.aclose()


def _canon(solutions) -> np.ndarray:
    arr = np.asarray(solutions)
    if arr.size == 0:
        return arr.reshape(0, arr.shape[1] if arr.ndim == 2 else 0)
    return arr[np.lexsort(arr.T[::-1])]


def _server_config() -> ServerConfig:
    return ServerConfig(selector_backend="kernel",
                        fast_path_rows=FAST_PATH_ROWS,
                        shard_window=SHARD_WINDOW)


def _oracle(wl) -> Dict[int, np.ndarray]:
    """Fault-free ground truth: one sequential client over one plain
    server, no batching, no faults -- the byte-parity reference."""
    server = BrTPFServer(dataset().store, _server_config())
    client = BrTPFClient(server)
    return {i: _canon(client.execute(bgp).solutions)
            for i, (_name, bgp) in enumerate(wl)}


def run_chaos(clients: int = 16, resilient: bool = True,
              seed: int = PLAN_SEED, smoke: bool = False,
              oracle: Optional[Dict[int, np.ndarray]] = None) -> Dict:
    """One chaos arm. ``resilient=False`` is the A/B control: same
    plan, same deadlines, but a bare transport -- no retries, no
    hedging (the router's breaker still runs; it is part of the server,
    not the client)."""
    wl = list(workload())[:4 if smoke else 12]
    if oracle is None:
        oracle = _oracle(wl)
    router = ReplicaRouter(dataset().store, _server_config(),
                           replicas=REPLICAS,
                           fault_plan=fault_plan(seed),
                           failure_threshold=2, reset_after_s=0.5)
    base = LoopbackTransport(router)
    if resilient:
        inner = ResilientTransport(base, RetryPolicy(
            max_attempts=MAX_ATTEMPTS, base_backoff_s=2e-3,
            max_backoff_s=0.05, deadline_ms=DEADLINE_MS,
            attempt_timeout_ms=ATTEMPT_TIMEOUT_MS,
            hedge=True), seed=seed)
    else:
        inner = base
    probe = _OutcomeTransport(inner)
    indexed = list(enumerate(wl))
    per_client = split_workload(indexed, clients)
    failed_queries = 0
    mismatches = 0
    solved = 0

    async def one(client, queries) -> None:
        nonlocal failed_queries, mismatches, solved
        for i, (_name, bgp) in queries:
            try:
                res = await client.execute(bgp)
            except Exception:
                # client-visible query failure -- the A/B arm's whole
                # point; counted, never retried here (the resilient arm
                # already retried below, consulting is_retryable)
                failed_queries += 1
                continue
            solved += 1
            if not np.array_equal(_canon(res.solutions), oracle[i]):
                mismatches += 1

    async def main() -> dict:
        cs = [AsyncBrTPFClient(
            probe,
            deadline_ms=None if resilient else AB_DEADLINE_MS)
            for _ in range(clients)]
        try:
            await asyncio.gather(*[
                one(c, w) for c, w in zip(cs, per_client, strict=True)])
            return await probe.metrics()
        finally:
            await probe.aclose()

    t0 = time.perf_counter()
    snap = asyncio.run(main())
    wall = time.perf_counter() - t0
    out = chaos_summary(probe.ok, probe.failed, failed_queries,
                        probe.samples_s, wall_s=wall,
                        parity=1.0 if mismatches == 0 else 0.0)
    res = snap.get("resilience", {})
    breaker = res.get("breaker", {})
    out.update({
        "clients": clients,
        "resilient": 1.0 if resilient else 0.0,
        "queries": len(wl),
        "solved_queries": solved,
        "wall_s": wall,
        "retries": res.get("retries", 0),
        "hedges": res.get("hedges", 0),
        "shed": res.get("shed", 0),
        "breaker_opens": breaker.get("opens", 0),
        "breaker_transitions": breaker.get("transitions", 0),
        "failovers": breaker.get("failovers", 0),
    })
    return out


def run_sweep(smoke: bool = False, clients: int = 16) -> Dict:
    wl = list(workload())[:4 if smoke else 12]
    oracle = _oracle(wl)
    out: Dict = {}
    r = run_chaos(clients=clients, resilient=True, smoke=smoke,
                  oracle=oracle)
    out[("chaos", clients)] = r
    emit(f"chaos/resilient_c{clients}", 0.0,
         f"success_rate={r['success_rate']:.4f};"
         f"parity={r['parity']:.0f};"
         f"failed_queries={r['failed_queries']};"
         f"retries={r['retries']};hedges={r['hedges']};"
         f"shed={r['shed']};breaker_opens={r['breaker_opens']};"
         f"failovers={r['failovers']};"
         f"p99={r['p99_latency_ms']:.1f}ms;wall={r['wall_s']:.1f}s")
    ab = run_chaos(clients=clients, resilient=False, smoke=smoke,
                   oracle=oracle)
    # tuple key: check_budgets resolves "chaos_ab_c16" by splitting at
    # the first "_c", which lands on the concurrency suffix
    out[("chaos_ab", clients)] = ab
    emit(f"chaos/ab_bare_c{clients}", 0.0,
         f"success_rate={ab['success_rate']:.4f};"
         f"failed_queries={ab['failed_queries']};"
         f"solved={ab['solved_queries']}/{ab['queries']};"
         f"wall={ab['wall_s']:.1f}s")
    return out


def headline_metrics(out: Dict, clients: int = 16) -> Dict:
    r = out.get(("chaos", clients))
    ab = out.get(("chaos_ab", clients))
    h: Dict = {}
    if r:
        h.update({
            "chaos_c16_success_rate": r["success_rate"],
            "chaos_c16_p99_latency_ms": r["p99_latency_ms"],
            "chaos_c16_retries": r["retries"],
            "chaos_c16_breaker_opens": r["breaker_opens"],
            "chaos_c16_failovers": r["failovers"],
        })
    if ab:
        h["chaos_ab_c16_failed_queries"] = ab["failed_queries"]
    return h


def run(full: bool = False) -> Dict:
    """benchmarks.run entry point (CSV rows via ``emit``)."""
    return run_sweep(smoke=not full)


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="chaos: closed-loop load under seeded fault plans")
    parser.add_argument("--smoke", action="store_true",
                        help="small workload + budget gate (CI job)")
    parser.add_argument("--clients", type=int, default=16)
    args = parser.parse_args(argv)
    cfg = BenchConfig.default()
    assert cfg is not None  # env-validated scales
    out = run_sweep(smoke=args.smoke, clients=args.clients)
    failures = check_budgets(out, path=BUDGETS_PATH)
    # Both paths persist a trajectory entry (the smoke run is what CI
    # executes per PR, and every PR must land one); smoke keys carry a
    # ``smoke_`` prefix so they never masquerade as full-run numbers.
    headline = headline_metrics(out, clients=args.clients)
    if args.smoke:
        headline = {f"smoke_{k}": v for k, v in headline.items()}
    path = persist("throughput", out, headline=headline,
                   section="chaos_smoke" if args.smoke else "chaos")
    print(f"# persisted -> {path}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
