"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU container, interpret-mode timings measure Python dispatch,
not TPU performance -- the derived column therefore also reports the
*work geometry* (compare-grid cells per launch) that the roofline model
uses for the TPU projection in EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bindjoin, ops, tpf_match
from repro.kernels import ref

from .common import emit


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(full: bool = False) -> Dict:
    rng = np.random.default_rng(0)
    out: Dict = {}
    shapes = [(4096, 30), (16384, 50)] if not full else [
        (4096, 30), (16384, 50), (65536, 128), (262144, 50)]
    for t, m in shapes:
        cand = jnp.asarray(rng.integers(0, 1000, (t, 3)), jnp.int32)
        pats = jnp.asarray(rng.integers(-1, 1000, (m, 3)), jnp.int32)
        valid = jnp.ones((m,), jnp.int32)

        dt_ref = _time(lambda: jax.block_until_ready(
            bindjoin(cand, pats, valid, use_pallas=False)))
        dt_pal = _time(lambda: jax.block_until_ready(
            bindjoin(cand, pats, valid, use_pallas=True)))
        cells = t * m
        out[(t, m)] = (dt_ref, dt_pal)
        emit(f"kernels/bindjoin_T{t}_M{m}_ref", dt_ref * 1e6,
             f"cells={cells}")
        emit(f"kernels/bindjoin_T{t}_M{m}_pallas_interp", dt_pal * 1e6,
             f"cells={cells}")

        vec = jnp.asarray(ops.pattern_vec_from((3, -1, -1)))
        dt_m = _time(lambda: jax.block_until_ready(
            tpf_match(cand, vec, use_pallas=False)))
        emit(f"kernels/tpf_match_T{t}_ref", dt_m * 1e6, f"rows={t}")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
