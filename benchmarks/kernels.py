"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU container, interpret-mode timings measure Python dispatch,
not TPU performance -- the derived column therefore also reports the
*work geometry* (compare-grid cells per launch) that the roofline model
uses for the TPU projection in EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bindjoin, ops, tpf_match
from repro.kernels import ref

from .common import emit, persist


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(full: bool = False) -> Dict:
    rng = np.random.default_rng(0)
    out: Dict = {}
    shapes = [(4096, 30), (16384, 50)] if not full else [
        (4096, 30), (16384, 50), (65536, 128), (262144, 50)]
    for t, m in shapes:
        cand = jnp.asarray(rng.integers(0, 1000, (t, 3)), jnp.int32)
        pats = jnp.asarray(rng.integers(-1, 1000, (m, 3)), jnp.int32)
        valid = jnp.ones((m,), jnp.int32)

        dt_ref = _time(lambda: jax.block_until_ready(
            bindjoin(cand, pats, valid, use_pallas=False)))
        dt_pal = _time(lambda: jax.block_until_ready(
            bindjoin(cand, pats, valid, use_pallas=True)))
        cells = t * m
        out[(t, m)] = (dt_ref, dt_pal)
        emit(f"kernels/bindjoin_T{t}_M{m}_ref", dt_ref * 1e6,
             f"cells={cells}")
        emit(f"kernels/bindjoin_T{t}_M{m}_pallas_interp", dt_pal * 1e6,
             f"cells={cells}")

        vec = jnp.asarray(ops.pattern_vec_from((3, -1, -1)))
        dt_m = _time(lambda: jax.block_until_ready(
            tpf_match(cand, vec, use_pallas=False)))
        emit(f"kernels/tpf_match_T{t}_ref", dt_m * 1e6, f"rows={t}")

    out["selector"] = run_selector_backends(full=full)
    path = persist("kernels", out)
    print(f"# persisted -> {path}")
    return out


def run_selector_backends(full: bool = False) -> Dict:
    """Selector-backend axis: the server-side brTPF selector evaluated
    by the numpy per-pattern backend loop vs the Pallas bind-join kernel
    path (solo and cross-request-batched grouped launches).

    On CPU the kernel runs in interpret mode, so its wall-clock column
    measures dispatch, not TPU speed; the geometry columns (candidates
    streamed per HBM pass, compare-grid cells, passes saved by batching)
    are the quantities the TPU cost model in ``core/sim.py`` charges.
    """
    import jax
    from jax.sharding import Mesh
    from repro.core.federation import FederatedStore, ShardedSelector
    from repro.core.kernel_selectors import KernelSelector
    from repro.core.rdf import UNBOUND, TriplePattern, encode_var
    from repro.core.selectors import brtpf_select_with_cnt
    from repro.core.store import TripleStore

    rng = np.random.default_rng(7)
    n_triples = 200_000 if full else 20_000
    triples = np.unique(
        rng.integers(0, 500, (n_triples, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    v = encode_var
    out: Dict = {}

    fed = FederatedStore.build(
        store.triples, Mesh(np.array(jax.devices()), ("data",)))

    def full_stream_omega(m, width):
        """Random mappings with one all-UNBOUND row: the base-shaped
        instantiation defeats sub-range pruning, so these rows measure
        the classic full-prefix-range stream (the pre-pruning geometry
        the cost model projects)."""
        om = rng.integers(0, 500, (m, width)).astype(np.int32)
        om[0] = UNBOUND
        return om

    def pruned_omega(m, positions):
        """Mappings sampled from real store rows (so sub-ranges are
        non-empty) binding exactly ``positions`` -> the Omega-restricted
        pruned stream."""
        picks = store.triples[rng.integers(0, len(store), (m,))]
        width = max(positions) + 1
        om = np.full((m, width), UNBOUND, np.int32)
        for var, pos in enumerate(positions):
            om[:, var] = picks[:, pos]
        return om

    cases = [
        ("bound_p", TriplePattern(v(0), 7, v(1)),
         full_stream_omega(30, 2)),
        ("wildcard", TriplePattern(v(0), v(1), v(2)),
         full_stream_omega(30, 3)),
        ("bound_p_small_omega", TriplePattern(v(0), 7, v(1)),
         full_stream_omega(5, 2)),
        # Omega-restricted pruning rows (docs/pruning.md): identical
        # patterns, mappings that instantiate more-bound shapes -- the
        # candidate stream shrinks to the sub-range union
        ("bound_p_pruned", TriplePattern(v(0), 7, v(1)),
         pruned_omega(30, (0, 2))),
        ("wildcard_pruned", TriplePattern(v(0), v(1), v(2)),
         pruned_omega(30, (0, 1))),
    ]
    for name, tp, omega in cases:
        omegas = [omega] + [
            np.stack([rng.integers(0, 500, (omega.shape[1],))
                      .astype(np.int32)
                      for _ in range(omega.shape[0])])
            for _ in range(7)
        ]
        sel = KernelSelector(store)

        dt_np = _time(lambda tp=tp, omega=omega:
                      brtpf_select_with_cnt(store, tp, omega))
        dt_k = _time(lambda tp=tp, omega=omega:
                     sel.select_with_cnt(tp, omega))
        sel.launches.clear()
        dt_b = _time(lambda tp=tp, omegas=omegas:
                     sel.select_same_pattern(tp, omegas))
        rec = sel.launches[-1] if sel.launches else None
        out[name] = (dt_np, dt_k, dt_b, rec)
        emit(f"kernels/selector_{name}_numpy", dt_np * 1e6,
             f"per_request")
        if rec is None:
            emit(f"kernels/selector_{name}_kernel_interp", dt_k * 1e6,
                 "cand=0;pruned_to_empty")
            continue
        solo_cells = rec.cand_streamed * (rec.pat_slots
                                          // max(rec.groups, 1))
        emit(f"kernels/selector_{name}_kernel_interp", dt_k * 1e6,
             f"cand={rec.cand_streamed};cells={solo_cells};"
             f"pruned={int(rec.pruned)};cand_full={rec.cand_full}")
        emit(f"kernels/selector_{name}_kernel_batch{len(omegas)}",
             dt_b * 1e6 / len(omegas),
             f"per_request;cand_shared={rec.cand_streamed};"
             f"cells={rec.cells};hbm_passes_saved={rec.groups - 1}")

        # sharded windowed backend: same selection, per-shard window
        # launches -- per-launch streaming is the window, not the range
        ssel = ShardedSelector(fed, window=2048)
        dt_s = _time(lambda tp=tp, omega=omega:
                     ssel.select_with_cnt(tp, omega), reps=2)
        ssel.launches.clear()
        ssel.select_with_cnt(tp, omega)  # launch count of ONE select
        n_launch = len(ssel.launches)
        per_launch = ssel.launches[-1] if ssel.launches else None
        out[name + "_sharded"] = (dt_s, n_launch, per_launch)
        window_rows = per_launch.cand_streamed if per_launch else 0
        emit(f"kernels/selector_{name}_sharded_interp", dt_s * 1e6,
             f"window={window_rows};"
             f"launches={n_launch};shards={fed.shards};"
             f"cand_total={window_rows * n_launch}")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
