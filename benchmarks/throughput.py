"""Paper Figure 3 (+ Appendix B): throughput under concurrent load.

Replays real engine traces through the calibrated discrete-event cluster
model (core/sim.py): one 4-worker server, N in {4, 16, 64} concurrent
clients, 5-minute query timeout, one simulated hour -- with and without
the shared HTTP cache (Figure 3 right column / section 7.2).

Validation targets: (C3) brTPF completes more queries than TPF at every
client count, TPF times out more, both scale with clients; (C4) the
cache raises both, TPF gains more (higher hit rate) but does not
overtake brTPF in completed queries; average QET grows slower for brTPF.

Selector-backend axis (beyond-paper): the brTPF workload is also traced
through the *kernel* selector backend (Pallas bind-join over the store's
candidate ranges) and replayed under the TPU launch cost model, with and
without cross-request batching (``SimParams.batch_window_s``), so the
server-side speedup of the kernel path is a measured comparison on the
same request streams, not an assertion.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.sim import (SimParams, calibrate, collect_traces,
                            simulate, split_workload)

from .common import BenchConfig, dataset, emit, make_server, workload


def run(full: bool = False) -> Dict:
    cfg = BenchConfig.default()
    wl = list(workload())
    client_counts = [4, 16, 64]
    out: Dict = {}

    # one trace collection per (client kind, selector backend) -- server
    # state is stateless across requests, so traces are reusable across
    # client counts
    server = make_server()
    params = calibrate(server, wl)
    if not full:
        # 10 simulated minutes keeps the event-granular replay fast; the
        # TPF-vs-brTPF comparison is horizon-independent
        params.duration_s = 600.0
    traces = {}
    for kind, backend, mpr in [("tpf", "numpy", None),
                               ("brtpf", "numpy", 30),
                               ("brtpf-kernel", "kernel", 30)]:
        server = make_server(max_mpr=mpr or 30, selector_backend=backend)
        traces[kind] = collect_traces(
            server, wl, kind.split("-")[0], max_mpr=mpr,
            request_budget=cfg.request_budget)

    for use_cache in (False, True):
        for n in client_counts:
            for kind in ("tpf", "brtpf"):
                per_client = split_workload(traces[kind], n)
                res = simulate(per_client, params,
                               cache_size=None, use_cache=use_cache,
                               wrap=True)
                key = (kind, n, use_cache)
                out[key] = res
                emit(
                    f"throughput/{kind}_c{n}"
                    f"{'_cache' if use_cache else ''}",
                    0.0,
                    f"completed_per_hr={res.throughput_per_hour:.0f};"
                    f"timeouts={res.timeouts};"
                    f"attempted_per_hr={res.attempts_per_hour:.0f};"
                    f"avg_qet={res.avg_qet:.2f}s;"
                    f"horizon={res.simulated_s:.0f}s")

    # selector-backend axis: same brTPF request streams, kernel launch
    # cost model, batching off vs on
    for n in client_counts:
        for label, window in [("batch0", 0.0), ("batch2ms", 2e-3)]:
            kp = dataclasses.replace(params, batch_window_s=window)
            per_client = split_workload(traces["brtpf-kernel"], n)
            res = simulate(per_client, kp, cache_size=None,
                           use_cache=False, wrap=True)
            out[("brtpf-kernel", n, label)] = res
            emit(
                f"throughput/brtpf_kernel_c{n}_{label}", 0.0,
                f"completed_per_hr={res.throughput_per_hour:.0f};"
                f"timeouts={res.timeouts};"
                f"avg_qet={res.avg_qet:.2f}s;"
                f"horizon={res.simulated_s:.0f}s")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
