"""Paper Figure 3 (+ Appendix B): throughput under concurrent load.

Replays real engine traces through the calibrated discrete-event cluster
model (core/sim.py): one 4-worker server, N in {4, 16, 64} concurrent
clients, 5-minute query timeout, one simulated hour -- with and without
the shared HTTP cache (Figure 3 right column / section 7.2).

Validation targets: (C3) brTPF completes more queries than TPF at every
client count, TPF times out more, both scale with clients; (C4) the
cache raises both, TPF gains more (higher hit rate) but does not
overtake brTPF in completed queries; average QET grows slower for brTPF.

Selector-backend axis (beyond-paper): the brTPF workload is also traced
through the *kernel* selector backend (Pallas bind-join over the store's
candidate ranges) and the *sharded* windowed backend (mesh-partitioned
store, fixed per-shard window launches) and replayed under the TPU
launch cost model, with and without cross-request batching
(``SimParams.batch_window_s``), so the server-side speedup of the
accelerated paths is a measured comparison on the same request streams,
not an assertion. ``run_hetero_mix`` A/Bs cross-pattern kernel fusion
(docs/fusion.md) on identical heterogeneous request streams -- fused vs
unfused launches-per-request, CI-gated via ``hetero_c16:*``;
``run_sharded_axis`` sweeps the sharded geometry
(per-shard window); ``run_warm_cache`` measures the unified fragment
store (a warm pass must skip every launch -- CI-gated via
``budgets.json`` ``warm_cache:*``); ``run_cache_axis`` reproduces the
section-7.1 TPF-vs-brTPF HTTP hit-rate comparison under an LRU
capacity sweep. The whole run persists to ``BENCH_throughput.json`` at
the repo root for cross-PR tracking.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict

from repro.core import (AsyncBrTPFClient, AsyncBrTPFServer, BrTPFClient,
                        LRUCache, layer_metrics)
from repro.core.sim import (calibrate, collect_traces, simulate,
                            split_workload)

from .common import (BenchConfig, emit, make_server, persist,
                     run_sequence, workload)

BUDGETS_PATH = os.path.join(os.path.dirname(__file__), "budgets.json")

# Per-shard window used by every sharded-backend measurement below (and
# by the budget gate): large enough that WatDiv CI-scale ranges take a
# handful of window launches, small enough that per-launch streaming
# stays an order of magnitude under the store size.
SHARD_WINDOW = 2048


def run(full: bool = False) -> Dict:
    cfg = BenchConfig.default()
    wl = list(workload())
    client_counts = [4, 16, 64]
    out: Dict = {}

    # one trace collection per (client kind, selector backend) -- server
    # state is stateless across requests, so traces are reusable across
    # client counts
    server = make_server()
    params = calibrate(server, wl)
    if not full:
        # 10 simulated minutes keeps the event-granular replay fast; the
        # TPF-vs-brTPF comparison is horizon-independent
        params.duration_s = 600.0
    traces = {}
    for kind, backend, mpr in [("tpf", "numpy", None),
                               ("brtpf", "numpy", 30),
                               ("brtpf-kernel", "kernel", 30),
                               ("brtpf-sharded", "sharded", 30)]:
        server = make_server(max_mpr=mpr or 30, selector_backend=backend,
                             shard_window=SHARD_WINDOW)
        traces[kind] = collect_traces(
            server, wl, kind.split("-")[0], max_mpr=mpr,
            request_budget=cfg.request_budget)

    for use_cache in (False, True):
        for n in client_counts:
            for kind in ("tpf", "brtpf"):
                per_client = split_workload(traces[kind], n)
                res = simulate(per_client, params,
                               cache_size=None, use_cache=use_cache,
                               wrap=True)
                key = (kind, n, use_cache)
                out[key] = res
                emit(
                    f"throughput/{kind}_c{n}"
                    f"{'_cache' if use_cache else ''}",
                    0.0,
                    f"completed_per_hr={res.throughput_per_hour:.0f};"
                    f"timeouts={res.timeouts};"
                    f"attempted_per_hr={res.attempts_per_hour:.0f};"
                    f"avg_qet={res.avg_qet:.2f}s;"
                    f"horizon={res.simulated_s:.0f}s")

    # selector-backend axis: same brTPF request streams, kernel launch
    # cost model (single-host kernel vs mesh-sharded windowed), batching
    # off vs on
    for kind in ("brtpf-kernel", "brtpf-sharded"):
        for n in client_counts:
            for label, window in [("batch0", 0.0), ("batch2ms", 2e-3)]:
                kp = dataclasses.replace(params, batch_window_s=window)
                per_client = split_workload(traces[kind], n)
                res = simulate(per_client, kp, cache_size=None,
                               use_cache=False, wrap=True)
                out[(kind, n, label)] = res
                emit(
                    f"throughput/{kind.replace('-', '_')}_c{n}_{label}",
                    0.0,
                    f"completed_per_hr={res.throughput_per_hour:.0f};"
                    f"timeouts={res.timeouts};"
                    f"launches_per_request="
                    f"{res.launches_per_request:.3f};"
                    f"avg_qet={res.avg_qet:.2f}s;"
                    f"horizon={res.simulated_s:.0f}s")
    return out


# ---------------------------------------------------------------------------
# Concurrency axis: REAL in-flight clients over the async front end
# ---------------------------------------------------------------------------


def _run_concurrent(backend: str, n: int, wl, request_budget: int,
                    batch_window_s: float = 2e-3,
                    max_batch: int = 64,
                    shard_window: int = SHARD_WINDOW,
                    fuse: bool = True,
                    per_client=None) -> Dict:
    """Run ``n`` concurrent AsyncBrTPFClients over one front end;
    returns wall-clock + launch accounting. ``per_client`` overrides
    the default round-robin partition with an explicit per-client
    workload assignment (the hetero-mix axis rotates overlapping
    subsets so every client stays busy with a different query)."""
    server = make_server(selector_backend=backend,
                         shard_window=shard_window,
                         fuse_patterns=fuse)
    front = AsyncBrTPFServer(server, batch_window_s=batch_window_s,
                             max_batch=max_batch)
    if per_client is None:
        per_client = split_workload(wl, n)

    async def main():
        clients = [AsyncBrTPFClient(front, request_budget=request_budget)
                   for _ in range(n)]
        try:
            return await asyncio.gather(
                *[c.run_workload(w)
                  for c, w in zip(clients, per_client, strict=True)])
        finally:
            await front.aclose()

    t0 = time.perf_counter()
    results = asyncio.run(main())
    wall = time.perf_counter() - t0
    c = server.counters
    reqs = max(c.num_requests, 1)
    return {
        "wall_s": wall,
        "requests": c.num_requests,
        "req_per_s": c.num_requests / max(wall, 1e-9),
        "launches": c.kernel_launches,
        "launches_per_request": c.kernel_launches / reqs,
        # per-device candidate rows streamed (window * launches on the
        # sharded backend, padded range buckets on the kernel backend)
        "cand_streamed": c.kernel_cand_streamed,
        "cand_streamed_per_request": c.kernel_cand_streamed / reqs,
        # Omega-restricted pruning + small-work fast path accounting
        "cand_pruned_away": c.cand_pruned_away,
        "fast_path_selects": c.fast_path_selects,
        "shard_window": shard_window if backend == "sharded" else 0,
        "shards": (server.federated.shards
                   if backend == "sharded" else 0),
        "batched_requests": c.kernel_batched_requests,
        # cross-pattern fusion accounting (docs/fusion.md): launches
        # that carried >= 2 pattern segments, and how many segments
        # each such launch amortised
        "fused_launches": c.fused_launches,
        "fused_launches_per_request": c.fused_launches / reqs,
        "fused_segments": c.fused_segments,
        "fused_segments_per_launch": (
            c.fused_segments / c.fused_launches
            if c.fused_launches else 0.0),
        # unified fragment store: launches avoided by residency + the
        # per-layer hit rates of the server's metrics snapshot
        "launches_skipped": c.launches_skipped,
        "launches_skipped_per_request": c.launches_skipped / reqs,
        "memo_hit_rate": server.fragments.hit_rate,
        "layers": layer_metrics(server),
        "fast_path": front.stats.fast_path,
        "flushes": front.stats.flushes,
        "mean_batch": front.stats.mean_batch,
        "completed": sum(sum(1 for r in rs if not r.timed_out)
                         for rs in results),
    }


def run_async(full: bool = False, smoke: bool = False) -> Dict:
    """Wall-clock concurrency axis: 1/4/16/64 in-flight clients on the
    real async batching front end, numpy vs kernel vs sharded backend."""
    cfg = BenchConfig.default()
    wl = list(workload())
    if smoke:
        wl = wl[:6]
        grid = [("kernel", 1), ("kernel", 8), ("sharded", 8)]
    else:
        if not full:
            wl = wl[:12]
        counts = [1, 4, 16, 64]
        grid = [(b, n) for b in ("numpy", "kernel", "sharded")
                for n in counts]
    out: Dict = {}
    for backend, n in grid:
        r = _run_concurrent(backend, n, wl, cfg.request_budget)
        out[(backend, n)] = r
        emit(
            f"throughput/async_{backend}_c{n}", 0.0,
            f"req_per_s={r['req_per_s']:.0f};"
            f"requests={r['requests']};"
            f"launches_per_request={r['launches_per_request']:.3f};"
            f"skipped_per_request="
            f"{r['launches_skipped_per_request']:.3f};"
            f"memo_hit_rate={r['memo_hit_rate']:.3f};"
            f"cand_per_request={r['cand_streamed_per_request']:.0f};"
            f"pruned_away={r['cand_pruned_away']};"
            f"fast_path_selects={r['fast_path_selects']};"
            f"batched={r['batched_requests']};"
            f"fast_path={r['fast_path']};"
            f"mean_batch={r['mean_batch']:.1f};"
            f"completed={r['completed']};"
            f"wall={r['wall_s']:.1f}s")
    return out


# ---------------------------------------------------------------------------
# Heterogeneous-mix axis: cross-pattern fusion under concurrent load
# ---------------------------------------------------------------------------


def run_hetero_mix(full: bool = False, smoke: bool = False) -> Dict:
    """Cross-pattern fusion axis (docs/fusion.md): N concurrent clients
    each working a *different* query subset, so every batching window
    holds a heterogeneous pattern mix (>= 4 distinct patterns in flight
    at N >= 4). Each client count runs twice on the kernel backend --
    fused and unfused -- on identical request streams, so the
    launches-per-request drop is a same-stream A/B, not a model
    estimate. ``launch_drop`` is the unfused/fused ratio; the CI gate
    (``budgets.json`` ``hetero_c16:*`` + ``hetero_unfused_c16:*``)
    bounds the fused side from above and the unfused side from below,
    which pins the drop at smoke scale.

    Client i works queries ``wl[i], wl[i+1], ... (mod len)`` -- rotated
    *overlapping* subsets rather than a disjoint partition, so no
    client finishes early and drains the mix into homogeneous
    single-pattern windows (a disjoint split at 16 clients leaves the
    straggler flushing alone, which is exactly the unfused regime)."""
    cfg = BenchConfig.default()
    wl = list(workload())
    if smoke:
        wl = wl[:8]
        counts = [16]
    else:
        if not full:
            wl = wl[:12]
        counts = [1, 4, 16, 64]
    per = min(4, len(wl))
    out: Dict = {}
    for n in counts:
        per_client = [[wl[(i + j) % len(wl)] for j in range(per)]
                      for i in range(n)]
        fused = _run_concurrent("kernel", n, wl, cfg.request_budget,
                                fuse=True, per_client=per_client)
        unfused = _run_concurrent("kernel", n, wl, cfg.request_budget,
                                  fuse=False, per_client=per_client)
        r = dict(fused)
        r["launches_unfused"] = unfused["launches"]
        r["launches_per_request_unfused"] = \
            unfused["launches_per_request"]
        r["launch_drop"] = (
            unfused["launches_per_request"]
            / max(fused["launches_per_request"], 1e-12))
        out[("hetero", n)] = r
        out[("hetero_unfused", n)] = unfused
        emit(
            f"throughput/hetero_c{n}", 0.0,
            f"launches_per_request={r['launches_per_request']:.3f};"
            f"unfused={r['launches_per_request_unfused']:.3f};"
            f"launch_drop={r['launch_drop']:.2f}x;"
            f"fused_launches_per_request="
            f"{r['fused_launches_per_request']:.3f};"
            f"fused_segments_per_launch="
            f"{r['fused_segments_per_launch']:.2f};"
            f"cand_per_request={r['cand_streamed_per_request']:.0f};"
            f"completed={r['completed']};"
            f"wall={r['wall_s']:.1f}s")
    return out


# ---------------------------------------------------------------------------
# Sharded axis: shards x window (the tentpole's perf claim)
# ---------------------------------------------------------------------------


def run_sharded_axis(full: bool = False) -> Dict:
    """Sweep the sharded backend's geometry: per-shard window size (and
    every shard the host exposes -- on a multi-device host the store is
    mesh-partitioned across all of them).

    The claim this axis demonstrates: candidates streamed per request
    are bounded by the *window* (one device's per-launch stream),
    independent of range/store/shard size -- versus the kernel backend,
    whose per-request stream is the pattern's padded range bucket.
    """
    cfg = BenchConfig.default()
    wl = list(workload())
    if not full:
        wl = wl[:12]
    windows = [256, 1024, 2048, 8192] if full else [512, 2048]
    out: Dict = {}
    for window in windows:
        r = _run_concurrent("sharded", 8, wl, cfg.request_budget,
                            shard_window=window)
        out[("sharded", 8, window)] = r
        emit(
            f"throughput/sharded_c8_w{window}", 0.0,
            f"shards={r['shards']};"
            f"req_per_s={r['req_per_s']:.0f};"
            f"launches_per_request={r['launches_per_request']:.3f};"
            f"cand_per_request={r['cand_streamed_per_request']:.0f};"
            f"completed={r['completed']};"
            f"wall={r['wall_s']:.1f}s")
    return out


# ---------------------------------------------------------------------------
# Workload-skew placement axis (docs/federation.md, "Placement")
# ---------------------------------------------------------------------------


def run_skew(timeout_s: float = 600.0) -> Dict:
    """Heat-based placement A/B under Zipf-skewed load.

    Runs ``benchmarks.skew`` in a subprocess: the A/B needs a real
    multi-shard mesh, and the forced host-platform device count must be
    set before jax initializes -- which, in this process, it already
    has. The module's last stdout line is one JSON row
    (:func:`repro.core.metrics.rebalance_report` + metadata), returned
    keyed as ``("skew", 16)`` so ``check_budgets`` resolves the
    ``skew_c16:*`` gates against it.
    """
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.skew"],
        capture_output=True, text=True, timeout=timeout_s,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(
            f"benchmarks.skew failed (rc={proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    row = json.loads(proc.stdout.strip().splitlines()[-1])
    emit(
        "throughput/skew_c16", 0.0,
        f"shards={row['shards']};"
        f"imbalance_uniform={row['imbalance_uniform']:.2f};"
        f"imbalance_heat={row['imbalance_heat']:.2f};"
        f"imbalance_drop={row['imbalance_drop']:.2f}x;"
        f"replica_ranges={row['replica_ranges']};"
        f"parity_ok={row['parity_ok']}")
    return {("skew", 16): row}


# ---------------------------------------------------------------------------
# Unified-fragment-store axes: warm-cache skips + section-7.1 capacity sweep
# ---------------------------------------------------------------------------


def run_warm_cache(smoke: bool = False, backend: str = "kernel",
                   queries: int = 6) -> Dict:
    """Warm-cache measurement for the unified fragment store.

    Runs the same brTPF query sequence twice against one server with an
    unlimited HTTP cache; the second (warm) pass must be served from
    the unified store -- near-zero kernel launches, one skipped launch
    per request, HTTP hit rate ~1. The two warm-pass ratios are gated
    in CI (``budgets.json``: ``warm_cache:*``).
    """
    cfg = BenchConfig.default()
    wl = list(workload())[:queries if smoke else 2 * queries]
    server = make_server(cache=LRUCache(None), selector_backend=backend,
                         shard_window=SHARD_WINDOW)

    def one_pass():
        for _name, bgp in wl:
            BrTPFClient(server,
                        request_budget=cfg.request_budget).execute(bgp)

    one_pass()                    # cold: populate every layer
    server.reset_counters()
    one_pass()                    # warm: must skip every launch
    c = server.counters
    reqs = max(c.num_requests, 1)
    r = {
        "requests": c.num_requests,
        "launches": c.kernel_launches,
        "launches_per_request": c.kernel_launches / reqs,
        "launches_skipped": c.launches_skipped,
        "launches_skipped_per_request": c.launches_skipped / reqs,
        "hit_rate": server.cache.hit_rate,
        "layers": layer_metrics(server),
    }
    emit(
        f"throughput/warm_cache_{backend}", 0.0,
        f"requests={r['requests']};"
        f"launches={r['launches']};"
        f"skipped_per_request={r['launches_skipped_per_request']:.3f};"
        f"hit_rate={r['hit_rate']:.3f}")
    return r


def run_cache_axis(full: bool = False) -> Dict:
    """Section 7.1 (paper Figure 4a as *rates*): TPF-vs-brTPF HTTP
    cache hit rates under an LRU capacity sweep (unlimited / 1k / 100
    entries), persisted with the throughput results.

    Validation targets: TPF's hit rate >> brTPF's at every capacity
    (distinct Omega attachments make distinct URLs), maxMpR=15 beats
    maxMpR=30 on hits, and shrinking capacity only lowers hit rates.
    The servers run the numpy oracle backend: these are the paper's
    HTTP-layer numbers, deliberately free of memo/kernel effects.
    """
    capacities = [None, 1000, 100]
    out: Dict = {}
    for label, kind, mpr in [("tpf", "tpf", 30),
                             ("brtpf15", "brtpf", 15),
                             ("brtpf30", "brtpf", 30)]:
        for cap in capacities:
            cache = LRUCache(cap)
            server, _results = run_sequence(kind, max_mpr=mpr,
                                            cache=cache)
            key = (label, "inf" if cap is None else cap)
            out[key] = {
                "capacity": cap,
                "hits": cache.hits,
                "misses": cache.misses,
                "hit_rate": cache.hit_rate,
                "requests": server.counters.num_requests,
            }
            emit(
                f"throughput/cache_{label}_cap{cap or 'inf'}", 0.0,
                f"hits={cache.hits};"
                f"hit_rate={cache.hit_rate:.3f};"
                f"requests={server.counters.num_requests}")
    return out


def check_budgets(results: Dict, path: str = BUDGETS_PATH) -> int:
    """Gate kernel-backend launch coalescing (and warm-cache reuse)
    against checked-in budgets.

    Budgets are *counts/rates*, not wall-clock times, so the gate is
    stable across CI machine speeds. A plain number is an upper bound;
    a ``{"min": x}`` / ``{"max": y}`` object bounds either side (the
    warm-cache gates are lower bounds: hit rates must not regress).
    Returns the number of violations.
    """
    with open(path) as fh:
        budgets = json.load(fh)
    failures = 0
    for key, limit in budgets.items():
        name, metric = key.rsplit(":", 1)
        backend, _, cn = name.partition("_c")
        if cn.isdigit():
            r = results.get((backend, int(cn)))
        else:
            r = results.get(name)
        if r is None:
            print(f"budget SKIP {key}: combination not measured")
            continue
        value = r[metric]
        if isinstance(limit, dict):
            lo, hi = limit.get("min"), limit.get("max")
            ok = ((lo is None or value >= lo)
                  and (hi is None or value <= hi))
            bound = " and ".join(
                s for s in ([f">= {lo}"] if lo is not None else [])
                + ([f"<= {hi}"] if hi is not None else []))
        else:
            ok = value <= limit
            bound = f"<= {limit}"
        print(f"budget {'OK  ' if ok else 'FAIL'} {key}: "
              f"{value:.3f} {bound}")
        failures += 0 if ok else 1
    return failures


def headline_metrics(out: Dict) -> Dict:
    """One flat dict of the run's headline numbers -- the per-PR
    trajectory entry appended to ``BENCH_throughput.json`` (PR id is
    attached by ``common.persist``), so the perf history is a diffable
    series instead of a single overwritten snapshot."""
    h: Dict = {}
    k1 = out.get("async", {}).get(("kernel", 1))
    if k1:
        h.update({
            "kernel_c1_req_per_s": k1["req_per_s"],
            "kernel_c1_launches_per_request": k1["launches_per_request"],
            "kernel_c1_cand_per_request":
                k1["cand_streamed_per_request"],
            "kernel_c1_fast_path_selects": k1["fast_path_selects"],
            "kernel_c1_cand_pruned_away": k1["cand_pruned_away"],
        })
    sharded = out.get("sharded_axis", {}).get(("sharded", 8, SHARD_WINDOW))
    if sharded:
        h.update({
            "sharded_c8_launches_per_request":
                sharded["launches_per_request"],
            "sharded_c8_cand_per_request":
                sharded["cand_streamed_per_request"],
        })
    hetero = out.get("hetero", {}).get(("hetero", 16))
    if hetero:
        h.update({
            "hetero_c16_launches_per_request":
                hetero["launches_per_request"],
            "hetero_c16_launches_per_request_unfused":
                hetero["launches_per_request_unfused"],
            "hetero_c16_launch_drop": hetero["launch_drop"],
            "hetero_c16_fused_launches_per_request":
                hetero["fused_launches_per_request"],
            "hetero_c16_fused_segments_per_launch":
                hetero["fused_segments_per_launch"],
        })
    warm = out.get("warm_cache")
    if warm:
        h["warm_cache_hit_rate"] = warm["hit_rate"]
    skew = out.get("skew", {}).get(("skew", 16))
    if skew:
        h.update({
            "skew_c16_imbalance_uniform": skew["imbalance_uniform"],
            "skew_c16_imbalance_heat": skew["imbalance_heat"],
            "skew_c16_imbalance_drop": skew["imbalance_drop"],
        })
    return h


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser()
    parser.add_argument("--full", action="store_true")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny concurrency run + budget gate (CI job 3)")
    parser.add_argument("--async-only", action="store_true",
                        help="skip the trace-replay simulation section")
    args = parser.parse_args(argv)
    if args.smoke:
        results = run_async(smoke=True)
        results.update(run_hetero_mix(smoke=True))
        results.update(run_skew())
        results["warm_cache"] = run_warm_cache(smoke=True)
        failures = check_budgets(results)
        # The smoke run is what CI executes per PR, so it must land the
        # PR's trajectory entry too (full runs previously were the only
        # writers, leaving PRs that only ran smoke absent from the
        # series). Smoke keys are ``smoke_``-prefixed so the reduced
        # concurrency sweep never masquerades as full-run numbers.
        headline = {f"smoke_{k}": v for k, v in
                    headline_metrics({"async": results,
                                      "hetero": results,
                                      "skew": results,
                                      "warm_cache":
                                          results["warm_cache"]}).items()}
        headline["smoke_budget_failures"] = failures
        path = persist("throughput", results, headline=headline,
                       section="smoke")
        print(f"# persisted -> {path}")
        return 1 if failures else 0
    out: Dict = {}
    if not args.async_only:
        out["replay"] = run(full=args.full)
    out["async"] = run_async(full=args.full)
    out["hetero"] = run_hetero_mix(full=args.full)
    out["sharded_axis"] = run_sharded_axis(full=args.full)
    out["skew"] = run_skew()
    out["warm_cache"] = run_warm_cache()
    out["cache_axis"] = run_cache_axis(full=args.full)
    path = persist("throughput", out, headline=headline_metrics(out))
    print(f"# persisted -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
