"""Workload-skew placement A/B: heat-based boundaries vs equal split.

The tentpole claim of docs/federation.md ("Placement"): under a
Zipf-skewed request mix, the legacy equal contiguous split concentrates
nearly every window launch on the shard that happens to own the hot key
band, while heat-based boundaries (plus hot-range replication) spread
the same traffic across the mesh. This module measures that claim on a
synthetic hot-band dataset:

1. build a sharded server (``placement_policy="heat"``) over a store
   whose subjects are contiguous in the SPO key space;
2. pass A: replay 16 Zipf-skewed brTPF request streams through the
   async front end against the *equal* split and snapshot the
   per-shard balance (``metrics_snapshot()["shards"]``);
3. ``server.repartition()`` -- cut new boundaries from the heat log
   recorded during pass A (and replicate the hottest sub-range);
4. pass B: replay the same streams against the placed store and
   snapshot the balance again;
5. assert fragment byte-parity: a sample of requests is answered by the
   numpy oracle, the kernel backend and the repartitioned sharded
   backend, and all three must return identical pages.

The final stdout line is one JSON object (:func:`repro.core.metrics.
rebalance_report` plus run metadata) -- ``benchmarks.throughput``
spawns this module as a subprocess (the forced 4-device host platform
must be configured before jax initializes) and gates
``skew_c16:imbalance_uniform`` / ``imbalance_heat`` /
``imbalance_drop`` from that row.
"""
from __future__ import annotations

import os

# Must run before jax initializes (transitively, via repro.core): the
# placement A/B is meaningless on a 1-device mesh, and the host-platform
# device count is fixed at backend init. An externally-set count wins.
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 "
        + os.environ.get("XLA_FLAGS", ""))

import json
import sys
from typing import Dict, List, Tuple

import numpy as np

from repro.core import LRUCache, ServerConfig  # noqa: F401  (jax init)
from repro.core.batching import serve_concurrent
from repro.core.metrics import rebalance_report
from repro.core.rdf import UNBOUND, TriplePattern, encode_var
from repro.core.server import BrTPFServer, Request
from repro.core.store import TripleStore

# Dataset geometry: subjects are contiguous blocks in the SPO key space,
# so "hot subjects" == "hot key band" and the equal split's imbalance is
# structural, not accidental.
N_SUBJECTS = 512
N_PREDICATES = 16
TRIPLES_PER_SUBJECT = 96          # 6 objects per (subject, predicate)
SUBJ_BASE = 1_000
PRED_BASE = 1
OBJ_BASE = 100_000

N_STREAMS = 16
REQUESTS_PER_CLIENT = 48
# Zipf exponent 2.0: the top subject alone draws ~60% of the traffic,
# which no boundary cut can split -- so the A/B exercises BOTH placement
# mechanisms (weighted boundaries for the splittable tail, hot-range
# replication + routed dedup for the un-splittable head).
ZIPF_A = 2.0

SHARD_WINDOW = 64


def build_triples() -> np.ndarray:
    """Synthetic hot-band dataset: unique (s, p, o) rows, subjects (and
    their per-predicate blocks) contiguous under the SPO sort."""
    s = np.repeat(np.arange(N_SUBJECTS), TRIPLES_PER_SUBJECT) + SUBJ_BASE
    j = np.tile(np.arange(TRIPLES_PER_SUBJECT), N_SUBJECTS)
    p = (j % N_PREDICATES) + PRED_BASE
    o = np.arange(s.size) + OBJ_BASE    # unique per row
    return np.stack([s, p, o], axis=1).astype(np.int32)


def build_streams(seed: int = 0) -> List[List[Request]]:
    """16 Zipf-skewed brTPF streams. Each request restricts the pattern
    ``(subject, ?p, ?o)`` with a 2-mapping Omega binding ``?p`` -- the
    mapping pair varies per request, so repeats of a hot subject are
    distinct fragments (they launch instead of riding the memo) exactly
    like distinct downstream join states would be in a real bind-join."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, N_SUBJECTS + 1, dtype=np.float64)
    weights = ranks ** -ZIPF_A
    weights /= weights.sum()
    streams: List[List[Request]] = []
    for _ in range(N_STREAMS):
        reqs: List[Request] = []
        for _ in range(REQUESTS_PER_CLIENT):
            subj = int(rng.choice(N_SUBJECTS, p=weights)) + SUBJ_BASE
            preds = rng.choice(N_PREDICATES, size=2, replace=False)
            omega = np.asarray(
                [[int(p) + PRED_BASE, UNBOUND] for p in preds],
                dtype=np.int32)
            tp = TriplePattern(subj, encode_var(0), encode_var(1))
            reqs.append(Request(tp, omega, page=0))
        streams.append(reqs)
    return streams


def _replay(server: BrTPFServer,
            streams: List[List[Request]]) -> Dict:
    """Replay the streams through the real async front end (immediate
    dispatch: the balance measurement wants one launch plan per request
    on both sides of the A/B) and return the per-shard balance."""
    serve_concurrent(server, streams, batch_window_s=0.0)
    return server.metrics_snapshot()["shards"]


def _parity_sample(streams: List[List[Request]],
                   rng: np.random.Generator,
                   k: int = 12) -> List[Request]:
    flat = [r for s in streams for r in s]
    idx = rng.choice(len(flat), size=min(k, len(flat)), replace=False)
    return [flat[i] for i in idx]


def check_parity(store: TripleStore, sharded: BrTPFServer,
                 sample: List[Request]) -> Tuple[bool, int]:
    """Every sampled request must come back byte-identical from the
    numpy oracle, the kernel backend, and the (repartitioned, replica-
    holding) sharded backend."""
    oracle = BrTPFServer(store, ServerConfig(selector_backend="numpy"))
    kernel = BrTPFServer(store, ServerConfig(selector_backend="kernel"))
    mismatches = 0
    for req in sample:
        frags = [srv.handle(req) for srv in (oracle, kernel, sharded)]
        base = frags[0]
        for frag in frags[1:]:
            if (not np.array_equal(np.asarray(base.data),
                                   np.asarray(frag.data))
                    or base.cnt != frag.cnt
                    or base.has_next != frag.has_next):
                mismatches += 1
    return mismatches == 0, mismatches


def run(seed: int = 0) -> Dict:
    triples = build_triples()
    store = TripleStore(triples)
    streams = build_streams(seed)

    config = ServerConfig(selector_backend="sharded",
                          shard_window=SHARD_WINDOW,
                          placement_policy="heat")
    server = BrTPFServer(store, config)
    shards = server.federated.shards

    uniform = _replay(server, streams)       # pass A: equal split
    server.repartition()                     # heat -> boundaries + replicas
    server.reset_counters()
    heat = _replay(server, streams)          # pass B: placed store

    placement = server.federated.placement
    n_replicas = sum(len(v) for v in placement.replicas.values())
    parity_ok, mismatches = check_parity(
        store, server, _parity_sample(streams, np.random.default_rng(seed)))

    row = rebalance_report(uniform, heat)
    row.update({
        "shards": shards,
        "requests": N_STREAMS * REQUESTS_PER_CLIENT,
        "replica_ranges": n_replicas,
        "parity_ok": parity_ok,
        "parity_mismatches": mismatches,
    })
    return row


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="placement A/B under Zipf-skewed load")
    parser.add_argument("--seed", type=int, default=0)
    args = parser.parse_args(argv)
    row = run(seed=args.seed)
    for k, v in row.items():
        if not isinstance(v, list):
            print(f"# skew/{k} = {v}", file=sys.stderr)
    print(json.dumps(row))                    # parsed by run_skew()
    return 0 if row["parity_ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
