"""Paper section 5.3, second experiment: page-size sensitivity.

Claim C2: page size (100..2000 data triples/page) has no considerable
impact on #req or dataRecv for either interface -- the relative
TPF/brTPF differences are page-size independent.
"""
from __future__ import annotations

from typing import Dict

from .common import emit, run_sequence, timed


def run(full: bool = False) -> Dict:
    sizes = [100, 250, 500, 1000, 2000] if full else [100, 500, 2000]
    out: Dict = {}
    for kind, mpr in [("tpf", None), ("brtpf", 15), ("brtpf", 30)]:
        label = kind if mpr is None else f"{kind}{mpr}"
        out[label] = {}
        for ps in sizes:
            (server, results), dt = timed(
                run_sequence, kind, page_size=ps,
                max_mpr=mpr if mpr else 30)
            row = {"req": server.counters.num_requests,
                   "recv": server.counters.data_received}
            out[label][ps] = row
            emit(f"pagesize/{label}_ps{ps}",
                 dt * 1e6 / max(len(results), 1),
                 f"req={row['req']};recv={row['recv']}")
    return out


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv)
