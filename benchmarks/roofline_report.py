"""Roofline report: aggregate dry-run artifacts into the baseline table.

Reads ``artifacts/dryrun/*.json`` (produced by repro.launch.dryrun) and
emits one row per (arch x shape x mesh) cell with the three roofline
terms, the dominant bottleneck, and the useful-compute ratio. This is
the source of EXPERIMENTS.md section Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import emit

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def load_records(mesh_filter: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        if path.endswith("skips.json"):
            continue
        with open(path) as f:
            rec = json.load(f)
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        recs.append(rec)
    return recs


def run(full: bool = False) -> List[Dict]:
    recs = load_records()
    if not recs:
        emit("roofline/no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun` first")
        return []
    for rec in recs:
        r = rec["roofline"]
        name = f"roofline/{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        emit(
            name,
            rec.get("compile_s", 0.0) * 1e6,
            f"compute={r['compute_s']:.4f}s;"
            f"memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;"
            f"dominant={r['dominant']};"
            f"useful_ratio={r['useful_flops_ratio']:.3f};"
            f"fraction={r['roofline_fraction']:.3f};"
            f"mem_gb={rec['memory_analysis']['temp_size_gb']:.1f}")
    skips = os.path.join(ART, "skips.json")
    if os.path.exists(skips):
        with open(skips) as f:
            for s in json.load(f):
                emit(f"roofline/{s['arch']}__{s['shape']}__SKIP", 0.0,
                     s["reason"])
    return recs


def markdown_table(mesh: str = "pod16x16") -> str:
    """EXPERIMENTS.md-ready table for one mesh."""
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | useful ratio | roofline frac | mem GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh):
        r = rec["roofline"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {rec['memory_analysis']['temp_size_gb']:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    if "--markdown" in sys.argv:
        mesh = sys.argv[sys.argv.index("--markdown") + 1] \
            if len(sys.argv) > sys.argv.index("--markdown") + 1 \
            else "pod16x16"
        print(markdown_table(mesh))
    else:
        run(full="--full" in sys.argv)
