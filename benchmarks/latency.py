"""Closed-loop latency/SLO load generator for the serving edge (PR 7).

Every prior benchmark measured req/s of in-process method calls; this
one drives the WIRE. N closed-loop :class:`~repro.core.client.
AsyncBrTPFClient`s (1/4/16/64) execute the WatDiv workload over a
transport that round-trips every request and response through the
brtpf/v1 envelope (``core/wire.py``):

* ``loopback`` -- :class:`~repro.serving.transport.LoopbackTransport`
  over one async front end: the serialization boundary without HTTP
  framing. This is the CI-gated configuration (``budgets.json``
  ``loopback:p95_latency_ms`` max / ``loopback:req_per_s`` min) --
  wall-clock dependent, so the bounds are deliberately loose, but a
  10x serialization regression trips them on any machine.
* ``asgi`` -- :class:`~repro.serving.transport.AsgiTransport` over the
  ASGI app (optionally with a replica router): the complete HTTP layer
  minus the socket.

Each transport is wrapped in a per-request timer; the run reports the
canonical latency schema (``core/metrics.py``: p50/p95/p99/mean ms +
closed-loop req/s) per concurrency level plus the *saturation*
throughput (max req/s over the sweep -- the knee of the closed-loop
curve), and persists a per-PR trajectory entry (p50/p95/p99 at c=16,
saturation req/s) to ``BENCH_throughput.json`` next to the throughput
series.
"""
from __future__ import annotations

import asyncio
import time
from typing import Dict, List, Optional

from repro.core import AsyncBrTPFClient, latency_summary
from repro.core.batching import AsyncBrTPFServer
from repro.core.config import ServerConfig
from repro.core.sim import split_workload
from repro.serving.http import app_from_config
from repro.serving.transport import AsgiTransport, LoopbackTransport

from .common import BenchConfig, FAST_PATH_ROWS, dataset, emit, persist, \
    workload
from .throughput import BUDGETS_PATH, SHARD_WINDOW, check_budgets

CLIENT_COUNTS = [1, 4, 16, 64]


class _TimingTransport:
    """Per-request latency probe around any transport (the closed-loop
    clients call ``handle`` exactly once per wire request)."""

    def __init__(self, inner) -> None:
        self.inner = inner
        self.samples_s: List[float] = []

    @property
    def max_mpr(self) -> int:
        return self.inner.max_mpr

    async def handle(self, req):
        t0 = time.perf_counter()
        frag = await self.inner.handle(req)
        self.samples_s.append(time.perf_counter() - t0)
        return frag

    async def metrics(self) -> dict:
        return await self.inner.metrics()

    async def aclose(self) -> None:
        await self.inner.aclose()


def _make_transport(kind: str, config: ServerConfig,
                    batch_window_s: float, replicas: int):
    store = dataset().store
    if kind == "loopback":
        front = AsyncBrTPFServer.from_config(
            store, config, batch_window_s=batch_window_s)
        return _TimingTransport(LoopbackTransport(front))
    if kind == "asgi":
        app = app_from_config(store, config,
                              batch_window_s=batch_window_s,
                              replicas=replicas)
        return _TimingTransport(AsgiTransport(app))
    raise ValueError(f"unknown transport kind {kind!r}")


def run_level(kind: str, clients: int, wl, request_budget: int,
              config: ServerConfig, batch_window_s: float = 2e-3,
              replicas: int = 1) -> Dict:
    """One closed-loop level: ``clients`` concurrent AsyncBrTPFClients
    over one timed transport; returns the canonical latency schema plus
    wire metrics read back over the same transport."""
    transport = _make_transport(kind, config, batch_window_s, replicas)
    per_client = split_workload(wl, clients)

    async def main():
        cs = [AsyncBrTPFClient(transport, request_budget=request_budget)
              for _ in range(clients)]
        try:
            await asyncio.gather(
                *[c.run_workload(w)
                  for c, w in zip(cs, per_client, strict=True)])
            return await transport.metrics()
        finally:
            await transport.aclose()

    t0 = time.perf_counter()
    wire_metrics = asyncio.run(main())
    wall = time.perf_counter() - t0
    out = latency_summary(transport.samples_s, wall_s=wall)
    counters = wire_metrics["counters"]
    out.update({
        "clients": clients,
        "transport": kind,
        "replicas": replicas,
        "wall_s": wall,
        # served-side accounting, read over the wire (GET /metrics keys
        # == in-process metrics_snapshot keys)
        "server_requests": counters["num_requests"],
        "launches": counters["kernel_launches"],
        "launches_skipped": counters["launches_skipped"],
        "batched_requests": counters["kernel_batched_requests"],
    })
    return out


def run_sweep(kinds=("loopback", "asgi"), smoke: bool = False,
              full: bool = False, replicas: int = 1) -> Dict:
    cfg = BenchConfig.default()
    config = ServerConfig(selector_backend="kernel",
                          fast_path_rows=FAST_PATH_ROWS,
                          shard_window=SHARD_WINDOW)
    wl = list(workload())
    if smoke:
        wl = wl[:6]
        counts = [1, 8]
    else:
        if not full:
            wl = wl[:12]
        counts = CLIENT_COUNTS
    out: Dict = {}
    for kind in kinds:
        for n in counts:
            r = run_level(kind, n, wl, cfg.request_budget, config,
                          replicas=replicas if kind == "asgi" else 1)
            out[(kind, n)] = r
            emit(
                f"latency/{kind}_c{n}", 0.0,
                f"p50={r['p50_latency_ms']:.2f}ms;"
                f"p95={r['p95_latency_ms']:.2f}ms;"
                f"p99={r['p99_latency_ms']:.2f}ms;"
                f"req_per_s={r['req_per_s']:.0f};"
                f"requests={r['requests']};"
                f"launches_skipped={r['launches_skipped']};"
                f"batched={r['batched_requests']};"
                f"wall={r['wall_s']:.1f}s")
        # closed-loop saturation: the knee of the req/s-vs-clients curve
        peak = max((out[(kind, n)] for n in counts),
                   key=lambda r: r["req_per_s"])
        out[(kind, "saturation")] = {
            "req_per_s": peak["req_per_s"],
            "clients": peak["clients"],
        }
        emit(f"latency/{kind}_saturation", 0.0,
             f"req_per_s={peak['req_per_s']:.0f};"
             f"at_clients={peak['clients']}")
    return out


def headline_metrics(out: Dict) -> Dict:
    """Per-PR trajectory entry: the SLO quantities at a fixed load
    point (c=16 loopback) + saturation throughput per transport."""
    h: Dict = {}
    anchor = out.get(("loopback", 16)) or out.get(("loopback", 8))
    if anchor:
        h.update({
            "latency_loopback_p50_ms": anchor["p50_latency_ms"],
            "latency_loopback_p95_ms": anchor["p95_latency_ms"],
            "latency_loopback_p99_ms": anchor["p99_latency_ms"],
            "latency_loopback_clients": anchor["clients"],
        })
    for kind in ("loopback", "asgi"):
        sat = out.get((kind, "saturation"))
        if sat:
            h[f"saturation_{kind}_req_per_s"] = sat["req_per_s"]
    return h


def main(argv=None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="closed-loop wire latency / saturation sweep")
    parser.add_argument("--full", action="store_true")
    parser.add_argument("--smoke", action="store_true",
                        help="tiny loopback run + budget gate (CI)")
    parser.add_argument("--replicas", type=int, default=1,
                        help="server replicas behind the ASGI router")
    args = parser.parse_args(argv)
    if args.smoke:
        out = run_sweep(kinds=("loopback",), smoke=True)
        # budget gate reads the c=8 smoke level under the plain name
        results = {"loopback": out[("loopback", 8)]}
        failures = check_budgets(results, path=BUDGETS_PATH)
        return 1 if failures else 0
    out = run_sweep(smoke=False, full=args.full, replicas=args.replicas)
    path = persist("throughput", out, headline=headline_metrics(out),
                   section="latency")
    print(f"# persisted -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
