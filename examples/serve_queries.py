"""End-to-end driver (the paper's kind): serve a query workload to many
concurrent clients through the brTPF server and report throughput.

This is paper section 6 in miniature: a WatDiv-like dataset, concurrent
clients split across distinct query sets, a 4-worker origin server with
calibrated service costs, a 5-minute timeout, with/without the shared
HTTP cache -- comparing the TPF and brTPF interfaces end to end.

Run:  PYTHONPATH=src python examples/serve_queries.py [--clients 16]
"""
import argparse

from repro.core.sim import (calibrate, collect_traces, simulate,
                            split_workload)
from repro.core import BrTPFServer
from repro.data.watdiv import WatDivScale, generate, generate_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--queries", type=int, default=48)
    ap.add_argument("--cache", action="store_true")
    ap.add_argument("--selector-backend",
                    choices=["numpy", "kernel", "sharded"],
                    default="numpy",
                    help="origin-server selector: numpy per-pattern loop,"
                         " the Pallas bind-join kernel path, or the"
                         " mesh-sharded windowed path")
    args = ap.parse_args()

    data = generate(WatDivScale(users=1000, products=400, reviews=1500),
                    seed=0)
    wl = generate_workload(data, num_queries=args.queries, seed=1)
    print(f"dataset: {data.num_triples} triples; "
          f"workload: {len(wl)} queries; clients: {args.clients}")

    params = calibrate(BrTPFServer(data.store), wl)
    rows = []
    for kind, mpr in [("tpf", None), ("brtpf", 30)]:
        server = BrTPFServer(data.store, max_mpr=mpr or 30,
                             selector_backend=args.selector_backend)
        traces = collect_traces(server, wl, kind, max_mpr=mpr,
                                request_budget=20_000)
        per_client = split_workload(traces, args.clients)
        for use_cache in ([False, True] if args.cache else [False]):
            res = simulate(per_client, params, use_cache=use_cache,
                           wrap=True)
            rows.append((kind, use_cache, res))

    print(f"\n{'client':8s} {'cache':6s} {'completed/hr':>12s} "
          f"{'timeouts':>8s} {'avg QET':>8s}")
    for kind, cached, res in rows:
        print(f"{kind:8s} {str(cached):6s} {res.completed:12d} "
              f"{res.timeouts:8d} {res.avg_qet:7.1f}s")
    print("\nbrTPF sustains more completed queries under the same load"
          " (paper section 6); the cache helps both but does not let"
          " TPF overtake (section 7).")


if __name__ == "__main__":
    main()
