"""End-to-end drivers for the serving edge, as a small click CLI.

Three subcommands over one WatDiv-like dataset:

* ``sim``   -- the original driver (the paper's kind): serve a query
  workload to many concurrent clients through the simulated origin and
  report throughput (paper section 6 in miniature).
* ``serve`` -- stand up the real HTTP edge: the brtpf/v1 ASGI app over
  an async front end (or a replica fleet with ``--replicas``), served
  by uvicorn (``pip install 'repro[serving]'``).
* ``query`` -- one-shot wire demo: POST a (br)TPF page request through
  the in-process ASGI app and print the brtpf/v1 fragment envelope.

Run:  PYTHONPATH=src python examples/serve_queries.py sim --clients 16
      PYTHONPATH=src python examples/serve_queries.py serve --replicas 2
      PYTHONPATH=src python examples/serve_queries.py query -s -1 -p 3053
"""
import json
import sys

try:
    import click
except ImportError:  # pragma: no cover - click ships with the dev env
    sys.exit("this example needs click (pip install click)")

from repro.core import BrTPFServer, Request, ServerConfig, TriplePattern
from repro.core.sim import (calibrate, collect_traces, simulate,
                            split_workload)
from repro.data.watdiv import WatDivScale, generate, generate_workload
from repro.serving.http import TestClient, app_from_config, run_app

BACKENDS = click.Choice(["numpy", "kernel", "sharded"])


def make_dataset(queries: int = 48):
    data = generate(WatDivScale(users=1000, products=400, reviews=1500),
                    seed=0)
    wl = generate_workload(data, num_queries=queries, seed=1)
    return data, wl


@click.group()
def cli():
    """brTPF serving-edge drivers (sim / serve / query)."""


@cli.command("sim")
@click.option("--clients", default=16, show_default=True)
@click.option("--queries", default=48, show_default=True)
@click.option("--cache", is_flag=True,
              help="also simulate with the shared HTTP cache")
@click.option("--selector-backend", type=BACKENDS, default="numpy",
              show_default=True,
              help="origin-server selector: numpy per-pattern loop, the"
                   " Pallas bind-join kernel path, or the mesh-sharded"
                   " windowed path")
def sim(clients, queries, cache, selector_backend):
    """Simulated concurrent-client throughput, TPF vs brTPF."""
    data, wl = make_dataset(queries)
    click.echo(f"dataset: {data.num_triples} triples; "
               f"workload: {len(wl)} queries; clients: {clients}")

    params = calibrate(BrTPFServer(data.store), wl)
    rows = []
    for kind, mpr in [("tpf", None), ("brtpf", 30)]:
        config = ServerConfig(max_mpr=mpr or 30,
                              selector_backend=selector_backend)
        server = BrTPFServer(data.store, config)
        traces = collect_traces(server, wl, kind, max_mpr=mpr,
                                request_budget=20_000)
        per_client = split_workload(traces, clients)
        for use_cache in ([False, True] if cache else [False]):
            res = simulate(per_client, params, use_cache=use_cache,
                           wrap=True)
            rows.append((kind, use_cache, res))

    click.echo(f"\n{'client':8s} {'cache':6s} {'completed/hr':>12s} "
               f"{'timeouts':>8s} {'avg QET':>8s}")
    for kind, cached, res in rows:
        click.echo(f"{kind:8s} {str(cached):6s} {res.completed:12d} "
                   f"{res.timeouts:8d} {res.avg_qet:7.1f}s")
    click.echo("\nbrTPF sustains more completed queries under the same"
               " load (paper section 6); the cache helps both but does"
               " not let TPF overtake (section 7).")


@cli.command("serve")
@click.option("--host", default="127.0.0.1", show_default=True)
@click.option("--port", default=8000, show_default=True)
@click.option("--replicas", default=1, show_default=True,
              help="origin replicas behind the front-end router")
@click.option("--policy", type=click.Choice(["pattern", "round_robin"]),
              default="pattern", show_default=True)
@click.option("--page-size", default=100, show_default=True)
@click.option("--max-mpr", default=30, show_default=True)
@click.option("--selector-backend", type=BACKENDS, default="numpy",
              show_default=True)
def serve(host, port, replicas, policy, page_size, max_mpr,
          selector_backend):
    """Serve the brtpf/v1 HTTP API over a real socket (uvicorn)."""
    data, _ = make_dataset()
    config = ServerConfig(page_size=page_size, max_mpr=max_mpr,
                          selector_backend=selector_backend)
    app = app_from_config(data.store, config, replicas=replicas,
                          policy=policy)
    click.echo(f"dataset: {data.num_triples} triples; replicas="
               f"{replicas} policy={policy} maxMpR={max_mpr}")
    click.echo(f"GET http://{host}:{port}/fragment?s=-1&p=3053&o=-2")
    try:
        run_app(app, host=host, port=port)
    except RuntimeError as exc:  # uvicorn not installed
        raise click.ClickException(str(exc)) from exc


@cli.command("query")
@click.option("-s", default=-1, show_default=True,
              help="subject term id (negative = variable)")
@click.option("-p", default=3053, show_default=True)
@click.option("-o", default=-2, show_default=True)
@click.option("--page", default=0, show_default=True)
@click.option("--omega", default=None,
              help="solution mappings as a JSON list of int lists")
@click.option("--max-mpr", default=30, show_default=True)
def query(s, p, o, page, omega, max_mpr):
    """POST one page request through the in-process ASGI app."""
    import numpy as np
    data, _ = make_dataset(queries=1)
    config = ServerConfig(max_mpr=max_mpr)
    req = Request(
        pattern=TriplePattern(s, p, o),
        omega=(None if omega is None
               else np.asarray(json.loads(omega), dtype=np.int32)),
        page=page)
    with TestClient(app_from_config(data.store, config)) as tc:
        resp = tc.post("/fragment", json_body=req.to_wire())
        click.echo(f"HTTP {resp.status_code}")
        env = resp.json()
        if resp.status_code == 200:
            click.echo(f"cnt={env['cnt']} page={env['page']} "
                       f"has_next={env['has_next']} "
                       f"triples={len(env['data'])} "
                       f"meta_triples={env['meta_triples']}")
            for row in env["data"][:10]:
                click.echo(f"  {row}")
            if len(env["data"]) > 10:
                click.echo(f"  ... {len(env['data']) - 10} more")
        else:
            click.echo(json.dumps(env, indent=1))


if __name__ == "__main__":
    cli()
