"""Distributed brTPF: the triple store sharded over a device mesh.

Each mesh shard acts as one brTPF server of a federation; a request
(triple pattern + attached bindings) is broadcast, the Pallas bind-join
kernel filters shard-locally, and fixed-capacity pages are all-gathered
back -- the paper's client/server split expressed as JAX collectives.

Run:  PYTHONPATH=src python examples/federation_demo.py
(single CPU device here; the dry-run lowers the same request step on the
 256/512-chip production meshes -- see EXPERIMENTS.md.)
"""
import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import (TriplePattern, TripleStore, brtpf_select,
                        encode_var)
from repro.core.federation import FederatedStore


def main() -> None:
    rng = np.random.default_rng(0)
    triples = np.unique(
        rng.integers(0, 64, size=(5000, 3)).astype(np.int32), axis=0)
    store = TripleStore(triples)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    fed = FederatedStore.build(store.triples, mesh)
    print(f"store: {len(store)} triples across {mesh.size} shard(s)")

    V = encode_var
    tp = TriplePattern(V(0), 7, V(1))
    omega = rng.integers(0, 64, size=(12, 2)).astype(np.int32)
    omega[rng.random((12, 2)) < 0.3] = -1

    got = fed.execute(tp, omega, max_mpr=16, capacity=1024)
    want = brtpf_select(store, tp, omega)
    assert (set(map(tuple, got.tolist()))
            == set(map(tuple, want.tolist())))
    print(f"brTPF request: pattern (?s 7 ?o) + {omega.shape[0]} bindings")
    print(f"distributed result: {got.shape[0]} triples "
          f"(== host oracle: {want.shape[0]})")

    # what actually crossed the wire, per the paper's argument:
    req_bytes = omega.nbytes + 3 * 4
    tpf_bytes = store.match(tp).shape[0] * 12
    brtpf_bytes = got.shape[0] * 12
    print(f"\nwire model: request {req_bytes} B; "
          f"TPF response would be {tpf_bytes} B; "
          f"brTPF response {brtpf_bytes} B "
          f"({100 * brtpf_bytes / max(tpf_bytes, 1):.1f}%)")


if __name__ == "__main__":
    main()
