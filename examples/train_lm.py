"""Train a small LM end-to-end through the brTPF data plane.

Data curation is a BGP query over the corpus metadata store executed by
the brTPF client (the paper's technique as the framework's data plane);
the selected documents stream into packed LM batches; training runs with
AdamW, async checkpointing, and automatic failure recovery.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --m100  # ~100M params
"""
import argparse
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.pipeline import BrTPFDataPipeline, SyntheticCorpus
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import AdamW, warmup_cosine


def make_config(m100: bool):
    base = get_arch("qwen2-1.5b")
    if m100:
        # ~100M-param qwen2-style config
        return dataclasses.replace(
            base, name="qwen2-100m", num_layers=8, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=8192, tie_embeddings=True)
    return dataclasses.replace(
        base, name="qwen2-20m", num_layers=4, d_model=384, num_heads=6,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
        tie_embeddings=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--m100", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = make_config(args.m100)
    model = build_model(cfg)
    print(f"arch: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    corpus = SyntheticCorpus.generate(num_docs=400,
                                      vocab_size=cfg.vocab_size, seed=0)
    pipe = BrTPFDataPipeline(
        corpus, "?d hasDomain code\n?d hasQuality q0",
        batch_size=args.batch, seq_len=args.seq)
    print(f"data plane: brTPF selected {pipe.stats.selected_docs} docs "
          f"({pipe.stats.num_requests} requests, "
          f"{pipe.stats.data_received} triples received)")

    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(3e-4, 20, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))

    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.gettempdir(), f"repro_train_{cfg.name}")
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                      ckpt_every=50),
        step_fn, params, opt_state)
    if trainer.try_resume():
        print(f"resumed from checkpoint at step {trainer.step}")

    def logged(it):
        for i, b in enumerate(it):
            yield {k: jnp.asarray(v) for k, v in b.items()}

    report = trainer.train(logged(iter(pipe)))
    first = report.losses[0] if report.losses else float("nan")
    print(f"steps: {report.steps_run}  restarts: {report.restarts}")
    print(f"loss: {first:.3f} -> {report.final_loss:.3f}")
    assert report.final_loss < first, "training did not reduce loss"
    print("ok: loss decreased through the brTPF-fed pipeline")


if __name__ == "__main__":
    main()
