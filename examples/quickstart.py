"""Quickstart: TPF vs brTPF on a small RDF graph.

Builds a toy dataset, runs the same BGP query through both client
algorithms against the same combined server, and prints the paper's
network metrics side by side.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (BrTPFClient, BrTPFServer, ServerConfig, TPFClient,
                        TermDictionary, evaluate_bgp_reference, parse_bgp,
                        store_from_ntriples)


def main() -> None:
    d = TermDictionary()
    rng = np.random.default_rng(0)
    lines = []
    for i in range(200):
        lines.append(f"user{i} livesIn city{rng.integers(6)}")
        for _ in range(3):
            lines.append(f"user{i} likes product{rng.integers(40)}")
    for p in range(40):
        lines.append(f"product{p} hasGenre genre{rng.integers(5)}")
    store = store_from_ntriples(lines, d)
    print(f"dataset: {len(store)} triples, {d.__len__()} terms")

    query = """
        ?u livesIn city0
        ?u likes ?p
        ?p hasGenre genre0
    """
    bgp = parse_bgp(query, d)
    expected = evaluate_bgp_reference(store.triples, bgp)
    print(f"query: 3-pattern BGP, {expected.shape[0]} solutions\n")

    header = f"{'client':8s} {'#req':>6s} {'dataRecv':>9s} {'solutions':>9s}"
    print(header)
    print("-" * len(header))
    for name, make in [
        ("TPF", lambda srv: TPFClient(srv)),
        ("brTPF", lambda srv: BrTPFClient(srv, max_mpr=30)),
    ]:
        server = BrTPFServer(store, ServerConfig(page_size=100, max_mpr=30))
        res = make(server).execute(bgp)
        assert np.array_equal(np.unique(res.solutions, axis=0), expected)
        print(f"{name:8s} {res.num_requests:6d} {res.data_received:9d} "
              f"{res.solutions.shape[0]:9d}")
    print("\nbrTPF computes the identical result with a fraction of the"
          " requests/transfer (paper section 5).")


if __name__ == "__main__":
    main()
